//! Pure, mergeable contrastive-divergence phase work-units.
//!
//! [`CdTrainer::epoch`] used to be one synchronous loop; this module
//! breaks it into the two primitives a *distributed* trainer needs:
//!
//! * [`collect_positive`] — run a shard of truth-table patterns through
//!   a chip (clamp, thermalize, sample) and accumulate the data-phase
//!   statistics;
//! * [`collect_negative`] — sample the free-running model distribution
//!   and accumulate the model-phase statistics.
//!
//! Both write into a [`GradAccum`]: raw per-pattern / per-phase **sums**
//! (never means), so accumulators from different dies merge exactly —
//! [`GradAccum::merge`] is element-wise addition with the same
//! permutation-safe merge/restrict contract as
//! [`crate::metrics::SwapStats`] and [`crate::metrics::FluxStats`], and
//! the property tests below pin associativity/commutativity down.
//! Because every pattern slot is owned by exactly one shard, merging
//! per-die accumulators in *any* order and then calling
//! [`GradAccum::gradient`] reproduces the single-die arithmetic
//! bit-for-bit (`rust/tests/train_service_equivalence.rs`).
//!
//! [`CdTrainer::epoch`]: crate::learning::CdTrainer::epoch

use anyhow::{ensure, Result};

use crate::chimera::{GateLayout, Topology};
use crate::problems::edge_index;

use super::TrainableChip;

/// The static description of one gate-learning problem that a phase
/// work-unit needs: where the gate sits, which couplers are learnable,
/// and the per-phase sampling budget. Built once by
/// [`phase_spec`] so the trainer and every remote worker derive the
/// *same* edge ordering (the [`GradAccum`] slot layout).
#[derive(Debug, Clone)]
pub struct PhaseSpec {
    /// Visible (terminal) spins, in dataset bit order.
    pub visible: Vec<usize>,
    /// All layout spins (visible then hidden), in layout order.
    pub spins: Vec<usize>,
    /// Learnable couplers as (i, j) spin pairs, in canonical order.
    pub edges: Vec<(usize, usize)>,
    /// Thermalization sweeps before sampling a phase (CD-k).
    pub k_sweeps: usize,
    /// Sample sweeps per pattern in the positive phase.
    pub samples_per_pattern: usize,
}

/// Learnable couplers of a gate layout: every intra-layout spin pair
/// that exists on the hardware graph, as (i, j, canonical edge index)
/// with i < j, in the order [`CdTrainer`] enables them. This is the
/// single source of the edge ordering shared by the trainer's shadow
/// weights and every [`GradAccum`] slot.
///
/// [`CdTrainer`]: crate::learning::CdTrainer
pub fn learnable_pairs(topo: &Topology, layout: &GateLayout) -> Vec<(usize, usize, usize)> {
    let spins = layout.spins();
    let mut edges = Vec::new();
    for (a, &i) in spins.iter().enumerate() {
        for &j in &spins[a + 1..] {
            if let Some(e) = edge_index(topo, i, j) {
                edges.push((i.min(j), i.max(j), e));
            }
        }
    }
    edges
}

/// Build the [`PhaseSpec`] for a gate layout and CD budget.
pub fn phase_spec(
    layout: &GateLayout,
    k_sweeps: usize,
    samples_per_pattern: usize,
) -> PhaseSpec {
    let topo = Topology::new();
    PhaseSpec {
        visible: layout.visible.clone(),
        spins: layout.spins(),
        edges: learnable_pairs(&topo, layout).into_iter().map(|(i, j, _)| (i, j)).collect(),
        k_sweeps,
        samples_per_pattern,
    }
}

/// Mergeable sufficient statistics of one CD epoch: raw sums of
/// ⟨m_i·m_j⟩ / ⟨m_i⟩ observations, kept **per pattern** for the clamped
/// (data) phase and pooled for the free (model) phase.
///
/// Sums — not means — so accumulation distributes: each positive slot
/// is owned by whichever die ran that pattern, the negative slot pools
/// every die's free chains, and [`GradAccum::merge`] is plain addition.
#[derive(Debug, Clone, PartialEq)]
pub struct GradAccum {
    /// Data phase: `pos_c[p][k]` = Σ m_i·m_j over pattern p's samples,
    /// for learnable edge k.
    pub pos_c: Vec<Vec<f64>>,
    /// Data phase: `pos_m[p][s]` = Σ m over pattern p's samples, for
    /// layout spin slot s.
    pub pos_m: Vec<Vec<f64>>,
    /// Data phase: samples collected per pattern.
    pub pos_n: Vec<u64>,
    /// Model phase: per-edge Σ m_i·m_j over free-running samples.
    pub neg_c: Vec<f64>,
    /// Model phase: per-spin-slot Σ m over free-running samples.
    pub neg_m: Vec<f64>,
    /// Model phase: samples collected.
    pub neg_n: u64,
}

impl GradAccum {
    /// Zeroed accumulator for `patterns` truth-table rows over `edges`
    /// learnable couplers and `spins` layout spins.
    pub fn new(patterns: usize, edges: usize, spins: usize) -> Self {
        Self {
            pos_c: vec![vec![0.0; edges]; patterns],
            pos_m: vec![vec![0.0; spins]; patterns],
            pos_n: vec![0; patterns],
            neg_c: vec![0.0; edges],
            neg_m: vec![0.0; spins],
            neg_n: 0,
        }
    }

    /// Number of pattern slots.
    pub fn patterns(&self) -> usize {
        self.pos_n.len()
    }

    /// Record one sampled chip state into pattern slot `p`'s data-phase
    /// counters.
    pub fn record_positive(&mut self, p: usize, spec: &PhaseSpec, state: &[i8]) {
        record_into(&mut self.pos_c[p], &mut self.pos_m[p], spec, state);
        self.pos_n[p] += 1;
    }

    /// Record one sampled chip state into the model-phase counters.
    pub fn record_negative(&mut self, spec: &PhaseSpec, state: &[i8]) {
        record_into(&mut self.neg_c, &mut self.neg_m, spec, state);
        self.neg_n += 1;
    }

    /// Merge another accumulator into this one (element-wise addition).
    /// Associative and commutative over shard order — the training
    /// coordinator may collect its dies' accumulators in any completion
    /// order and still compute the same gradient, exactly like
    /// [`crate::metrics::SwapStats::merge`].
    pub fn merge(&mut self, other: &GradAccum) {
        assert_eq!(self.pos_n.len(), other.pos_n.len(), "pattern count mismatch");
        assert_eq!(self.neg_c.len(), other.neg_c.len(), "edge count mismatch");
        assert_eq!(self.neg_m.len(), other.neg_m.len(), "spin count mismatch");
        for p in 0..self.pos_n.len() {
            for k in 0..self.neg_c.len() {
                self.pos_c[p][k] += other.pos_c[p][k];
            }
            for s in 0..self.neg_m.len() {
                self.pos_m[p][s] += other.pos_m[p][s];
            }
            self.pos_n[p] += other.pos_n[p];
        }
        for k in 0..self.neg_c.len() {
            self.neg_c[k] += other.neg_c[k];
        }
        for s in 0..self.neg_m.len() {
            self.neg_m[s] += other.neg_m[s];
        }
        self.neg_n += other.neg_n;
    }

    /// Copy with only the listed pattern slots kept (other patterns
    /// zeroed, the pooled negative phase cleared) — the attribution
    /// helper mirroring [`crate::metrics::SwapStats::restricted`]:
    /// complementary restrictions merge back to the positive-phase
    /// counters, and the negative phase (like round trips there) is
    /// global and claimed by no single shard.
    pub fn restricted(&self, patterns: &[usize]) -> GradAccum {
        let mut out = GradAccum::new(self.pos_n.len(), self.neg_c.len(), self.neg_m.len());
        for &p in patterns {
            out.pos_c[p] = self.pos_c[p].clone();
            out.pos_m[p] = self.pos_m[p].clone();
            out.pos_n[p] = self.pos_n[p];
        }
        out
    }

    /// The CD gradient: (⟨·⟩_data − ⟨·⟩_model) per learnable edge and
    /// per layout spin, with every pattern's mean weighted equally (the
    /// uniform data distribution of a truth table).
    ///
    /// Fails when any pattern slot or the model phase collected no
    /// samples — a shard went missing, not a number to paper over.
    ///
    /// The arithmetic (per-pattern mean, divide by the pattern count,
    /// accumulate in pattern order, subtract the model mean) is exactly
    /// the legacy [`CdTrainer::epoch`] sequence, which is what makes
    /// the 1-die service run bit-identical to the synchronous trainer.
    ///
    /// [`CdTrainer::epoch`]: crate::learning::CdTrainer::epoch
    pub fn gradient(&self) -> Result<(Vec<f64>, Vec<f64>)> {
        let np = self.pos_n.len();
        ensure!(np > 0, "no pattern slots");
        ensure!(self.neg_n > 0, "model phase collected no samples");
        let ne = self.neg_c.len();
        let nb = self.neg_m.len();
        let mut dc = vec![0.0; ne];
        let mut dm = vec![0.0; nb];
        for p in 0..np {
            ensure!(self.pos_n[p] > 0, "pattern {p} collected no samples (shard missing?)");
            let nf = self.pos_n[p] as f64;
            for k in 0..ne {
                dc[k] += (self.pos_c[p][k] / nf) / np as f64;
            }
            for s in 0..nb {
                dm[s] += (self.pos_m[p][s] / nf) / np as f64;
            }
        }
        let nf = self.neg_n as f64;
        for k in 0..ne {
            dc[k] -= self.neg_c[k] / nf;
        }
        for s in 0..nb {
            dm[s] -= self.neg_m[s] / nf;
        }
        Ok((dc, dm))
    }
}

fn record_into(c: &mut [f64], m: &mut [f64], spec: &PhaseSpec, state: &[i8]) {
    for (k, &(i, j)) in spec.edges.iter().enumerate() {
        c[k] += (state[i] * state[j]) as f64;
    }
    for (k, &s) in spec.spins.iter().enumerate() {
        m[k] += state[s] as f64;
    }
}

/// Positive-phase work-unit: for each pattern of the shard (in order),
/// clamp the visible spins, thermalize `k_sweeps`, then collect
/// `samples_per_pattern` sample sweeps into the accumulator's slot
/// `first_pattern + local index`. The chip-call sequence is exactly the
/// legacy trainer's, so a whole-dataset shard on one die reproduces it
/// bit-for-bit.
pub fn collect_positive<C: TrainableChip>(
    chip: &mut C,
    spec: &PhaseSpec,
    patterns: &[Vec<i8>],
    first_pattern: usize,
    acc: &mut GradAccum,
) -> Result<()> {
    for (local, pattern) in patterns.iter().enumerate() {
        let clamps: Vec<(usize, i8)> =
            spec.visible.iter().copied().zip(pattern.iter().copied()).collect();
        chip.set_clamps(&clamps);
        chip.sweeps(spec.k_sweeps)?;
        let slot = first_pattern + local;
        for _ in 0..spec.samples_per_pattern {
            chip.sweeps(1)?;
            // borrow, don't clone: states() would deep-copy the whole
            // batch once per sample sweep
            chip.for_each_state(&mut |_, st| acc.record_positive(slot, spec, st));
        }
    }
    Ok(())
}

/// Negative-phase work-unit: release the clamps, optionally thermalize
/// `k_sweeps` (CD; persistent-chain dies skip the burn-in after their
/// first epoch), then collect `samples` sample sweeps of the
/// free-running model into the accumulator's pooled negative slot.
pub fn collect_negative<C: TrainableChip>(
    chip: &mut C,
    spec: &PhaseSpec,
    samples: usize,
    burn_in: bool,
    acc: &mut GradAccum,
) -> Result<()> {
    chip.set_clamps(&[]);
    if burn_in {
        chip.sweeps(spec.k_sweeps)?;
    }
    for _ in 0..samples {
        chip.sweeps(1)?;
        chip.for_each_state(&mut |_, st| acc.record_negative(spec, st));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chimera::and_gate_layout;

    fn spec() -> PhaseSpec {
        phase_spec(&and_gate_layout(0, 0), 2, 4)
    }

    fn random_state(rng: &mut crate::rng::HostRng) -> Vec<i8> {
        (0..crate::N_SPINS).map(|_| rng.spin()).collect()
    }

    fn random_accum(rng: &mut crate::rng::HostRng, spec: &PhaseSpec, patterns: usize) -> GradAccum {
        let mut a = GradAccum::new(patterns, spec.edges.len(), spec.spins.len());
        for _ in 0..rng.below(30) {
            let st = random_state(rng);
            if rng.uniform() < 0.5 {
                let p = rng.below(patterns);
                a.record_positive(p, spec, &st);
            } else {
                a.record_negative(spec, &st);
            }
        }
        a
    }

    #[test]
    fn spec_matches_the_and_block() {
        let s = spec();
        // AND layout: 3 visible × 4 hidden = 12 learnable couplers
        assert_eq!(s.edges.len(), 12);
        assert_eq!(s.spins.len(), 7);
        assert_eq!(s.visible.len(), 3);
        assert!(s.edges.iter().all(|&(i, j)| i < j));
    }

    #[test]
    fn gradient_of_matching_phases_is_zero() {
        let s = spec();
        let mut a = GradAccum::new(2, s.edges.len(), s.spins.len());
        let mut rng = crate::rng::HostRng::new(3);
        let st = random_state(&mut rng);
        a.record_positive(0, &s, &st);
        a.record_positive(1, &s, &st);
        a.record_negative(&s, &st);
        let (dc, dm) = a.gradient().unwrap();
        assert!(dc.iter().all(|&d| d.abs() < 1e-12), "{dc:?}");
        assert!(dm.iter().all(|&d| d.abs() < 1e-12), "{dm:?}");
    }

    #[test]
    fn gradient_requires_every_slot_filled() {
        let s = spec();
        let mut a = GradAccum::new(2, s.edges.len(), s.spins.len());
        let st = vec![1i8; crate::N_SPINS];
        a.record_positive(0, &s, &st);
        a.record_negative(&s, &st);
        // pattern 1 never sampled: a missing shard must be an error
        assert!(a.gradient().is_err());
        a.record_positive(1, &s, &st);
        assert!(a.gradient().is_ok());
    }

    #[test]
    fn restricted_keeps_only_listed_patterns() {
        let s = spec();
        let mut rng = crate::rng::HostRng::new(7);
        let a = {
            let mut a = GradAccum::new(4, s.edges.len(), s.spins.len());
            for p in 0..4 {
                for _ in 0..3 {
                    let st = random_state(&mut rng);
                    a.record_positive(p, &s, &st);
                }
            }
            a.record_negative(&s, &random_state(&mut rng));
            a
        };
        let r = a.restricted(&[1, 3]);
        assert_eq!(r.pos_n, vec![0, 3, 0, 3]);
        assert_eq!(r.neg_n, 0, "restriction never claims the model phase");
        // complementary restrictions merge back to the positive counters
        let mut merged = a.restricted(&[0, 2]);
        merged.merge(&r);
        assert_eq!(merged.pos_n, a.pos_n);
        assert_eq!(merged.pos_c, a.pos_c);
        assert_eq!(merged.pos_m, a.pos_m);
    }

    /// Property: merging per-shard accumulators is commutative and
    /// associative — the coordinator may collect dies in any completion
    /// order and still see the same counters.
    #[test]
    fn prop_merge_is_associative_and_commutative() {
        let s = spec();
        crate::util::prop::check("grad-accum merge", 100, |rng| {
            let patterns = rng.below(4) + 1;
            let a = random_accum(rng, &s, patterns);
            let b = random_accum(rng, &s, patterns);
            let c = random_accum(rng, &s, patterns);
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab.pos_c, ba.pos_c);
            assert_eq!(ab.pos_m, ba.pos_m);
            assert_eq!(ab.pos_n, ba.pos_n);
            assert_eq!(ab.neg_c, ba.neg_c);
            assert_eq!(ab.neg_n, ba.neg_n);
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            assert_eq!(ab_c.pos_c, a_bc.pos_c);
            assert_eq!(ab_c.neg_c, a_bc.neg_c);
            assert_eq!(ab_c.neg_m, a_bc.neg_m);
            assert_eq!(ab_c.pos_n, a_bc.pos_n);
        });
    }

    /// Property: sharding patterns over dies and merging reproduces the
    /// single-accumulator gradient bit-for-bit (each pattern slot is
    /// owned by exactly one shard; merging adds zeros elsewhere).
    #[test]
    fn prop_sharded_merge_reproduces_single_gradient() {
        let s = spec();
        crate::util::prop::check("grad-accum shard equivalence", 60, |rng| {
            let patterns = rng.below(5) + 2;
            let shards = rng.below(patterns) + 1;
            // the reference: every pattern and the model phase in one place
            let mut single = GradAccum::new(patterns, s.edges.len(), s.spins.len());
            let mut per_pattern_states: Vec<Vec<Vec<i8>>> = Vec::new();
            for p in 0..patterns {
                let mut sts = Vec::new();
                for _ in 0..rng.below(4) + 1 {
                    let st = random_state(rng);
                    single.record_positive(p, &s, &st);
                    sts.push(st);
                }
                per_pattern_states.push(sts);
            }
            let neg_states: Vec<Vec<i8>> =
                (0..rng.below(6) + 1).map(|_| random_state(rng)).collect();
            for st in &neg_states {
                single.record_negative(&s, st);
            }
            // the sharded version: contiguous pattern ranges + split negs
            let mut parts: Vec<GradAccum> = (0..shards)
                .map(|_| GradAccum::new(patterns, s.edges.len(), s.spins.len()))
                .collect();
            for p in 0..patterns {
                let owner = p * shards / patterns;
                for st in &per_pattern_states[p] {
                    parts[owner].record_positive(p, &s, st);
                }
            }
            for (i, st) in neg_states.iter().enumerate() {
                parts[i % shards].record_negative(&s, st);
            }
            let mut merged = GradAccum::new(patterns, s.edges.len(), s.spins.len());
            for part in &parts {
                merged.merge(part);
            }
            let (dc_a, dm_a) = single.gradient().unwrap();
            let (dc_b, dm_b) = merged.gradient().unwrap();
            // positive slots are owned by one shard each → exact; the
            // pooled negative sums are integer-valued → exact too
            assert_eq!(dc_a, dc_b);
            assert_eq!(dm_a, dm_b);
        });
    }
}
