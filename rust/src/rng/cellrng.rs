//! Per-cell 32-bit LFSRs and the chip-level RNG bank.
//!
//! Each Chimera unit cell holds one 32-bit LFSR advanced by its decimated
//! clock. A 32-bit register yields only 4 unique 8-bit lanes per cycle,
//! but each cell needs 8 random codes (one per p-bit); the die routes the
//! **normal bit sequence to the 4 vertical nodes and the bit-reversed
//! sequence to the 4 horizontal nodes** (paper, RNG paragraph). The RNG
//! DAC converts each 8-bit code to a uniform differential current in
//! (−1, +1) full-scale.

use super::decimator::{DecimatedClocks, N_USED};
use super::lfsr::{Lfsr, LFSR32_TAPS};

/// One unit cell's 32-bit LFSR with the normal/reversed lane split.
#[derive(Debug, Clone)]
pub struct CellRng {
    lfsr: Lfsr,
}

impl CellRng {
    /// One cell LFSR from a (forced-nonzero) power-up seed.
    pub fn new(seed: u64) -> Self {
        Self { lfsr: Lfsr::new(32, &LFSR32_TAPS, seed) }
    }

    /// Advance one cell clock.
    pub fn clock(&mut self) {
        self.lfsr.step();
    }

    /// Raw 32-bit register (hot-path lane access).
    #[inline]
    pub fn state32(&self) -> u32 {
        self.lfsr.state() as u32
    }

    /// The four 8-bit lanes of the register (normal bit order) — routed
    /// to the vertical p-bits k = 0..3.
    pub fn vertical_codes(&self) -> [u8; 4] {
        let s = self.lfsr.state() as u32;
        [(s >> 24) as u8, (s >> 16) as u8, (s >> 8) as u8, s as u8]
    }

    /// The same four lanes bit-reversed — routed to the horizontal
    /// p-bits k = 0..3.
    pub fn horizontal_codes(&self) -> [u8; 4] {
        let v = self.vertical_codes();
        [v[0].reverse_bits(), v[1].reverse_bits(), v[2].reverse_bits(), v[3].reverse_bits()]
    }

    /// All 8 codes in spin order (vertical 0..3, horizontal 0..3).
    pub fn codes(&self) -> [u8; 8] {
        let v = self.vertical_codes();
        let h = self.horizontal_codes();
        [v[0], v[1], v[2], v[3], h[0], h[1], h[2], h[3]]
    }
}

/// Map an 8-bit RNG-DAC code to a uniform value in (−1, 1).
///
/// The differential DAC output is (code − 127.5)/128, covering ±255/256
/// of full scale in 256 equal steps — strictly inside (−1, 1), matching
/// a real ladder whose top code lands one LSB short of the reference.
#[inline]
pub fn code_to_uniform(code: u8) -> f32 {
    (code as f32 - 127.5) / 128.0
}

/// Precomputed DAC transfer (hot-path form of [`code_to_uniform`]).
static UNIFORM_LUT: [f32; 256] = {
    let mut lut = [0.0f32; 256];
    let mut c = 0usize;
    while c < 256 {
        lut[c] = (c as f32 - 127.5) / 128.0;
        c += 1;
    }
    lut
};

/// Same transfer through the bit-reversed lane routing (horizontal
/// p-bits): LUT over the un-reversed code.
static UNIFORM_REV_LUT: [f32; 256] = {
    let mut lut = [0.0f32; 256];
    let mut c = 0usize;
    while c < 256 {
        lut[c] = ((c as u8).reverse_bits() as f32 - 127.5) / 128.0;
        c += 1;
    }
    lut
};

/// The whole chip's RNG: decimator + 55 cell LFSRs.
#[derive(Debug, Clone)]
pub struct ChipRngBank {
    clocks: DecimatedClocks,
    cells: Vec<CellRng>,
}

impl ChipRngBank {
    /// Whole-chip RNG from one seed: the decimator plus per-cell LFSRs
    /// with distinct derived power-up states.
    pub fn new(seed: u64) -> Self {
        let cells = (0..N_USED)
            .map(|k| {
                // distinct per-cell power-up states (silicon would have
                // random flop init; we make it reproducible).
                let s = splitmix64(seed.wrapping_add(0x100 + k as u64));
                CellRng::new(s)
            })
            .collect();
        Self { clocks: DecimatedClocks::new(seed), cells }
    }

    /// Number of active cell LFSRs (55 on this die).
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Advance one 200 MHz master cycle: clock the cells whose derived
    /// clock fired. Returns the enable word for observability.
    pub fn master_cycle(&mut self) -> u64 {
        let en = self.clocks.step_used();
        let mut w = en;
        while w != 0 {
            let k = w.trailing_zeros() as usize;
            self.cells[k].clock();
            w &= w - 1;
        }
        en
    }

    /// Master cycles per sample period before the end-of-period strobe.
    const REFRESH_CYCLES: usize = 48;

    /// One sample period of RNG activity: 48 decimated master cycles,
    /// then an end-of-period strobe that clocks any cell the decimator
    /// missed — every cell advances ≥ once per sample, bounded work.
    pub fn refresh_all(&mut self) {
        let mut pending = (1u64 << N_USED) - 1;
        for _ in 0..Self::REFRESH_CYCLES {
            pending &= !self.master_cycle();
            if pending == 0 {
                break;
            }
        }
        // end-of-period strobe (the chip's sample clock forces a final
        // shift on lagging cells so no p-bit sees a stale random twice)
        while pending != 0 {
            let k = pending.trailing_zeros() as usize;
            self.cells[k].clock();
            pending &= pending - 1;
        }
    }

    /// Current uniform values for every spin of every cell,
    /// `[cell][spin-in-cell]`, in (−1, 1).
    pub fn uniforms(&self) -> Vec<[f32; 8]> {
        self.cells
            .iter()
            .map(|c| {
                let codes = c.codes();
                std::array::from_fn(|i| code_to_uniform(codes[i]))
            })
            .collect()
    }

    /// Fill a flat `[N_PAD]` slab with per-spin uniforms (padding = 0).
    pub fn fill_slab(&mut self, slab: &mut [f32]) {
        self.refresh_all();
        for (cell, c) in self.cells.iter().enumerate() {
            // hot path: LUT lookups straight off the register lanes
            // (identical values to code_to_uniform / reverse_bits).
            let s = c.state32();
            let base = cell * 8;
            let bytes = [(s >> 24) as u8, (s >> 16) as u8, (s >> 8) as u8, s as u8];
            slab[base] = UNIFORM_LUT[bytes[0] as usize];
            slab[base + 1] = UNIFORM_LUT[bytes[1] as usize];
            slab[base + 2] = UNIFORM_LUT[bytes[2] as usize];
            slab[base + 3] = UNIFORM_LUT[bytes[3] as usize];
            slab[base + 4] = UNIFORM_REV_LUT[bytes[0] as usize];
            slab[base + 5] = UNIFORM_REV_LUT[bytes[1] as usize];
            slab[base + 6] = UNIFORM_REV_LUT[bytes[2] as usize];
            slab[base + 7] = UNIFORM_REV_LUT[bytes[3] as usize];
        }
        for v in slab.iter_mut().skip(self.cells.len() * 8) {
            *v = 0.0;
        }
    }
}

/// SplitMix64 finalizer: one golden-ratio increment and two
/// multiply-xorshift rounds — the crate's standard way to derive
/// decorrelated seeds from nearby integers (per-cell power-up states
/// here; per-chain noise banks in `sampler::NoiseSource`).
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_layout() {
        let c = CellRng::new(0x1234_5678);
        let v = c.vertical_codes();
        assert_eq!(v, [0x12, 0x34, 0x56, 0x78]);
        let h = c.horizontal_codes();
        assert_eq!(h[0], 0x12u8.reverse_bits());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut acc = 0.0f64;
        let n = 256;
        for code in 0..=255u8 {
            let u = code_to_uniform(code);
            assert!(u > -1.0 && u < 1.0);
            acc += u as f64;
        }
        assert!((acc / n as f64).abs() < 1e-6, "DAC not symmetric");
    }

    #[test]
    fn bank_refresh_clocks_every_cell() {
        let mut bank = ChipRngBank::new(5);
        let before: Vec<[u8; 8]> = bank.cells.iter().map(|c| c.codes()).collect();
        bank.refresh_all();
        let after: Vec<[u8; 8]> = bank.cells.iter().map(|c| c.codes()).collect();
        let changed = before.iter().zip(&after).filter(|(b, a)| b != a).count();
        assert_eq!(changed, N_USED, "refresh_all must clock all 55 cells");
    }

    #[test]
    fn slab_fills_all_active_lanes() {
        let mut bank = ChipRngBank::new(9);
        let mut slab = vec![9.0f32; crate::N_PAD];
        bank.fill_slab(&mut slab);
        assert!(slab[..440].iter().all(|&u| (-1.0..1.0).contains(&u)));
        assert!(slab[440..].iter().all(|&u| u == 0.0));
    }

    /// The paper flags the normal/reversed sequence trick as a possible
    /// correlation source but reports no degradation; quantify it: the
    /// correlation between a lane and its reversal across time must be
    /// small.
    #[test]
    fn reversed_lane_correlation_is_small() {
        let mut c = CellRng::new(0xBEEF);
        let n = 20_000;
        let (mut sv, mut sh, mut svh, mut svv, mut shh) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            c.clock();
            let v = code_to_uniform(c.vertical_codes()[0]) as f64;
            let h = code_to_uniform(c.horizontal_codes()[0]) as f64;
            sv += v;
            sh += h;
            svh += v * h;
            svv += v * v;
            shh += h * h;
        }
        let nf = n as f64;
        let cov = svh / nf - (sv / nf) * (sh / nf);
        let corr = cov
            / ((svv / nf - (sv / nf).powi(2)).sqrt() * (shh / nf - (sh / nf).powi(2)).sqrt());
        assert!(corr.abs() < 0.05, "normal/reversed correlation {corr}");
    }

    #[test]
    fn distinct_cells_decorrelated() {
        let mut bank = ChipRngBank::new(2);
        let mut agree = 0usize;
        let n = 2_000;
        for _ in 0..n {
            bank.refresh_all();
            let u = bank.uniforms();
            if (u[0][0] > 0.0) == (u[1][0] > 0.0) {
                agree += 1;
            }
        }
        let frac = agree as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.06, "cells 0/1 sign agreement {frac}");
    }
}
