//! Fibonacci linear-feedback shift registers.

/// Maximal-length taps for a 32-bit LFSR (x³² + x²² + x² + x + 1),
/// expressed as bit positions (0-based) XORed into the feedback.
pub const LFSR32_TAPS: [u32; 4] = [31, 21, 1, 0];

/// Maximal-length taps for a 63-bit LFSR (x⁶³ + x⁶² + 1) — used for the
/// two fast seed LFSRs feeding the decimator.
pub const LFSR63_TAPS: [u32; 2] = [62, 61];

/// A Fibonacci LFSR over up to 64 bits.
///
/// `step()` shifts left by one, feeding back the XOR of the tap bits;
/// the output bit is the bit shifted out (MSB of the register).
#[derive(Debug, Clone)]
pub struct Lfsr {
    state: u64,
    width: u32,
    /// OR of 1<<tap — feedback computed branchlessly via popcount parity.
    tap_mask: u64,
}

impl Lfsr {
    /// Create with a nonzero seed (an all-zero LFSR is stuck; the seed is
    /// forced nonzero the way the chip's reset tree does).
    pub fn new(width: u32, taps: &[u32], seed: u64) -> Self {
        assert!(width >= 2 && width <= 64, "width {width} out of range");
        assert!(taps.iter().all(|&t| t < width), "tap beyond width");
        let mask = Self::mask_for(width);
        let mut state = seed & mask;
        if state == 0 {
            state = 1; // hardware reset forces a lane high
        }
        let tap_mask = taps.iter().fold(0u64, |acc, &t| acc | (1u64 << t));
        Self { state, width, tap_mask }
    }

    fn mask_for(width: u32) -> u64 {
        if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        }
    }

    /// Current register contents.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Register width in bits.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Advance one clock; returns the output (shifted-out) bit.
    #[inline]
    pub fn step(&mut self) -> u8 {
        let out = ((self.state >> (self.width - 1)) & 1) as u8;
        // XOR of the tap bits == parity of state & tap_mask (branchless).
        let fb = ((self.state & self.tap_mask).count_ones() & 1) as u64;
        self.state = ((self.state << 1) | fb) & Self::mask_for(self.width);
        out
    }

    /// Advance `n` clocks, returning the last output bit.
    pub fn step_n(&mut self, n: usize) -> u8 {
        let mut last = 0;
        for _ in 0..n {
            last = self.step();
        }
        last
    }

    /// Read `bits` output bits MSB-first as an integer.
    pub fn next_bits(&mut self, bits: u32) -> u64 {
        assert!(bits <= 64);
        let mut v = 0u64;
        for _ in 0..bits {
            v = (v << 1) | self.step() as u64;
        }
        v
    }

    /// The low `bits` of the raw register (the chip taps register lanes
    /// directly rather than serializing, for the per-cell value reads).
    pub fn window(&self, bits: u32) -> u64 {
        self.state & Self::mask_for(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_seed_unsticks() {
        let mut l = Lfsr::new(8, &[7, 5, 4, 3], 0);
        assert_ne!(l.state(), 0);
        l.step_n(100);
        assert_ne!(l.state(), 0);
    }

    #[test]
    fn small_lfsr_is_maximal_length() {
        // 8-bit maximal taps x^8+x^6+x^5+x^4+1 → period 255.
        let taps = [7, 5, 4, 3];
        let mut l = Lfsr::new(8, &taps, 0xA5);
        let start = l.state();
        let mut period = 0usize;
        loop {
            l.step();
            period += 1;
            if l.state() == start || period > 300 {
                break;
            }
        }
        assert_eq!(period, 255);
    }

    #[test]
    fn lfsr32_taps_give_long_period() {
        // Don't walk 2^32 states; check no short cycle within 1e6 steps.
        let mut l = Lfsr::new(32, &LFSR32_TAPS, 0xDEADBEEF);
        let start = l.state();
        for i in 1..=1_000_000usize {
            l.step();
            assert!(!(l.state() == start && i < 1_000_000), "short cycle at {i}");
        }
    }

    #[test]
    fn output_bits_balanced() {
        let mut l = Lfsr::new(32, &LFSR32_TAPS, 12345);
        let n = 100_000;
        let ones: u32 = (0..n).map(|_| l.step() as u32).sum();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "bit bias {frac}");
    }

    #[test]
    fn window_reads_low_bits() {
        let l = Lfsr::new(32, &LFSR32_TAPS, 0x1234_5678);
        assert_eq!(l.window(8), 0x78);
        assert_eq!(l.window(16), 0x5678);
    }

    #[test]
    #[should_panic]
    fn tap_beyond_width_panics() {
        Lfsr::new(8, &[8], 1);
    }
}
