//! Random-number substrate: the chip's decimated-LFSR RNG, reproduced
//! structurally, plus a fast splitmix/xoshiro generator for host-side
//! sampling (mismatch personalities, workloads).
//!
//! On the die (paper, RNG section): bitstreams from **two LFSRs clocked at
//! 200 MHz** are decimated into **64 unique random clocks**, of which
//! **55** drive a **32-bit LFSR in each Chimera unit cell**. Each cell
//! LFSR yields only 4 unique 8-bit values per cycle, so the **vertical
//! nodes read the normal bit sequence and the horizontal nodes the
//! reversed sequence** — trading a possible correlation for area, which
//! the paper reports as harmless and which `tests` quantify.

mod cellrng;
mod decimator;
mod lfsr;
mod pcg;

pub use cellrng::{code_to_uniform, splitmix64, CellRng, ChipRngBank};
pub use decimator::{DecimatedClocks, N_CLOCKS, N_USED};
pub use lfsr::{Lfsr, LFSR32_TAPS, LFSR63_TAPS};
pub use pcg::HostRng;
