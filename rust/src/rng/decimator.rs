//! Decimated LFSR clock generation.
//!
//! The paper: "Bitstreams from two LFSRs clocked at 200 MHz were used as
//! 64 unique random clocks of which 55 were used to drive a 32 bit LFSR
//! in each unit cell". We model the decimator the way Laskin-style
//! dividers do it: the two fast LFSR bitstreams are combined and each of
//! the 64 derived clocks fires when its 6-bit phase code matches the
//! current combined state, so every cell LFSR advances on a pseudo-random
//! subset of master cycles — decorrelating cells that share the same
//! silicon RNG structure.

use super::lfsr::{Lfsr, LFSR63_TAPS};

/// Number of derived random clocks.
pub const N_CLOCKS: usize = 64;
/// Clocks actually wired to unit cells (one per active cell).
pub const N_USED: usize = 55;

/// The two-LFSR decimator producing 64 random clock-enable lines.
#[derive(Debug, Clone)]
pub struct DecimatedClocks {
    a: Lfsr,
    b: Lfsr,
}

impl DecimatedClocks {
    /// Decimator seeded from one chip seed (both fast LFSRs derive
    /// distinct nonzero states from it).
    pub fn new(seed: u64) -> Self {
        // Two independent fast LFSRs; distinct derived seeds.
        let a = Lfsr::new(63, &LFSR63_TAPS, seed ^ 0x9E37_79B9_7F4A_7C15);
        let b = Lfsr::new(63, &LFSR63_TAPS, seed.wrapping_mul(0xBF58_476D_1CE4_E5B9) | 1);
        Self { a, b }
    }

    /// Advance one 200 MHz master cycle; returns a 64-bit word whose bit
    /// `k` is the clock-enable of derived clock `k` this cycle.
    ///
    /// Each fast LFSR advances once per master cycle and the decimator
    /// taps a 3-bit window of each register (register-lane taps, like
    /// the per-cell value reads) to form the 6-bit phase code — one shift
    /// per LFSR per cycle, as on the die.
    #[inline]
    pub fn step(&mut self) -> u64 {
        self.a.step();
        self.b.step();
        let code = ((self.a.window(3) as usize) | ((self.b.window(3) as usize) << 3)) & 0x3F;
        // Clock `code` fires, plus its complement lane — two enables per
        // cycle keeps the average cell-clock rate at 1/32 of master.
        (1u64 << code) | (1u64 << (code ^ 0x3F))
    }

    /// Enables for the 55 used clocks only (low 55 bits).
    pub fn step_used(&mut self) -> u64 {
        self.step() & ((1u64 << N_USED) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_enables_per_cycle() {
        let mut d = DecimatedClocks::new(7);
        for _ in 0..1000 {
            let w = d.step();
            assert_eq!(w.count_ones(), 2);
        }
    }

    #[test]
    fn all_clocks_eventually_fire() {
        let mut d = DecimatedClocks::new(3);
        let mut seen = 0u64;
        for _ in 0..100_000 {
            seen |= d.step();
        }
        assert_eq!(seen, u64::MAX, "some derived clock never fired");
    }

    #[test]
    fn firing_rate_is_near_uniform() {
        let mut d = DecimatedClocks::new(11);
        let mut counts = [0u32; N_CLOCKS];
        let n = 200_000;
        for _ in 0..n {
            let w = d.step();
            for (k, c) in counts.iter_mut().enumerate() {
                *c += ((w >> k) & 1) as u32;
            }
        }
        let expect = (2.0 * n as f64) / N_CLOCKS as f64;
        for (k, &c) in counts.iter().enumerate() {
            let ratio = c as f64 / expect;
            assert!((0.8..1.2).contains(&ratio), "clock {k} rate ratio {ratio}");
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut d1 = DecimatedClocks::new(1);
        let mut d2 = DecimatedClocks::new(2);
        let same = (0..10_000).filter(|_| d1.step() == d2.step()).count();
        assert!(same < 1000, "seeds produce near-identical clock streams");
    }
}
