//! Host-side PRNG (splitmix64-seeded xoshiro256++) for everything that is
//! *not* chip randomness: mismatch personalities, workload generation,
//! test fixtures. Deterministic, dependency-free, not cryptographic.

/// xoshiro256++ with convenience samplers.
#[derive(Debug, Clone)]
pub struct HostRng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

impl HostRng {
    /// Generator with state expanded from `seed` via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// N(mu, sigma²).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Random spin ±1.
    #[inline]
    pub fn spin(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = HostRng::new(42);
        let mut b = HostRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = HostRng::new(1);
        let n = 100_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            acc += u;
        }
        assert!((acc / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = HostRng::new(2);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn spin_balanced() {
        let mut r = HostRng::new(3);
        let s: i32 = (0..100_000).map(|_| r.spin() as i32).sum();
        assert!(s.abs() < 2_000);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = HostRng::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
