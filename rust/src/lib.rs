//! # pchip — a CMOS probabilistic-computing chip, reproduced in software
//!
//! Reproduction of *"A CMOS Probabilistic Computing Chip With In-situ
//! Hardware Aware Learning"* (Jhonsa et al., UCSB, 2025): a 440-spin
//! p-bit Ising machine on a Chimera graph with an analog current-mode
//! update path, whose process-variation mismatch is absorbed by
//! hardware-aware contrastive-divergence learning.
//!
//! The crate is the **L3 coordinator** of a three-layer stack:
//!
//! * **L1** — pallas kernels (`python/compile/kernels/`): the p-bit
//!   update and correlation hot-spots, MXU-shaped.
//! * **L2** — the jax chip model (`python/compile/model.py`), AOT-lowered
//!   once to HLO text artifacts (`python -m compile.aot`).
//! * **L3** — this crate: circuit-level substrates (analog standard-cell
//!   models, decimated-LFSR RNG, SPI), the cycle-accurate chip simulator,
//!   PJRT-backed and pure-rust samplers, the CD trainer, annealing / TTS
//!   and a replica-exchange (parallel tempering) engine, the problem
//!   library, and an async job coordinator. Python never runs on the
//!   request path.
//!
//! The paper-figure → module map and the quickstart live in the
//! top-level `README.md`; `docs/ARCHITECTURE.md` walks the three layers
//! and the coordinator's job lifecycle in detail.
//!
//! Two sampling modes are first-class: a β-ramp anneal
//! ([`annealing::anneal`], the paper's Fig 9a) and replica exchange
//! ([`annealing::temper`]) — K replicas on a [`annealing::BetaLadder`]
//! trading temperatures through Metropolis swap moves, served through
//! the coordinator as [`coordinator::JobRequest::Tempering`]. One
//! ladder can further be **sharded across the die array**
//! ([`coordinator::run_sharded_tempering`],
//! [`coordinator::JobRequest::ShardedTempering`]): dies sweep their
//! rung ranges concurrently and meet at barrier-synchronized swap
//! phases, bit-identical to the single-die engine in the 1-shard case
//! (`rust/tests/sharded_equivalence.rs`).
//!
//! The in-situ learning loop scales the same way: the **training
//! service** ([`learning::service`], served as
//! [`coordinator::JobRequest::Train`], CLI `pchip train --dies N`)
//! decomposes each contrastive-divergence epoch into pure, mergeable
//! phase work-units ([`learning::grad`]) and fans them across the die
//! array — every die samples both phases through its own mismatch
//! personality, the gradients all-reduce exactly, and a 1-die run is
//! bit-identical to the synchronous [`learning::CdTrainer`]
//! (`rust/tests/train_service_equivalence.rs`). Persistent (PCD) and
//! tempered negative phases plus JSON checkpoint/resume ride on top
//! (`docs/TRAINING.md`).
//!
//! The β-ladder the tempering modes run on is itself tunable:
//! [`annealing::tune_ladder`] runs Katzgraber-style round-trip-flux
//! feedback (measure the up-mover profile in [`metrics::FluxStats`],
//! re-space with [`annealing::BetaLadder::flux_respaced`], auto-size K)
//! and the coordinator serves it as
//! [`coordinator::JobRequest::TuneLadder`]; `docs/TUNING.md` is the
//! practitioner guide.
//!
//! The PJRT path is behind the `xla` cargo feature; the default build
//! substitutes a stub [`runtime`] so everything else works without an
//! `xla_extension` install.

// Every public item in this crate is part of the reproduction's API
// surface; CI builds docs with `RUSTDOCFLAGS="-D warnings"`, so a public
// item without docs fails the build instead of rotting silently.
#![warn(missing_docs)]

pub mod analog;
pub mod annealing;
pub mod chimera;
pub mod chip;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod learning;
pub mod metrics;
pub mod problems;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod spi;
pub mod telemetry;
pub mod transport;
pub mod util;

/// Number of physical spins on the die (7x8 Chimera cells, one replaced
/// by bias/SPI circuitry: 55 cells x 8 spins).
pub const N_SPINS: usize = 440;
/// Spin vector length after MXU padding (7 x 64).
pub const N_PAD: usize = 448;
