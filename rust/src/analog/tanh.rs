//! Winner-take-all tanh circuit (Fig 4, after Lazzaro et al. 1988).
//!
//! The WTA stage pins the current-summing node (better current matching)
//! and computes the tanh of eqn (2): each branch is a Fermi function of
//! the current difference and their subtraction yields tanh. Mismatch
//! appears as a per-instance **slope** (effective β multiplier, from tail
//! current and device Gm spread) and an **input-referred offset** (which
//! also absorbs the downstream comparator offset).

use crate::rng::HostRng;

/// One WTA tanh instance with frozen mismatch.
#[derive(Debug, Clone, Copy)]
pub struct WtaTanh {
    /// Slope mismatch multiplying the global β (nominal 1).
    pub slope: f64,
    /// Input-referred offset current (nominal 0).
    pub offset: f64,
}

impl WtaTanh {
    /// Draw one instance from the mismatch corner (slope floored at
    /// 0.05 — a dead tanh stage would make its p-bit deterministic).
    pub fn sample(rng: &mut HostRng, sigma_slope: f64, sigma_offset: f64) -> Self {
        Self {
            slope: rng.normal_ms(1.0, sigma_slope).max(0.05),
            offset: rng.normal_ms(0.0, sigma_offset),
        }
    }

    /// A perfectly matched instance.
    pub fn ideal() -> Self {
        Self { slope: 1.0, offset: 0.0 }
    }

    /// tanh(β · slope · I + offset): the differential activation fed to
    /// the comparator.
    #[inline]
    pub fn activate(&self, beta: f64, current: f64) -> f64 {
        (beta * self.slope * current + self.offset).tanh()
    }

    /// The two Fermi branches whose difference is `activate` — exposed
    /// for the Fig 8a transfer-curve experiment, which measures each
    /// branch via the chip's bias sweep.
    pub fn fermi_branches(&self, beta: f64, current: f64) -> (f64, f64) {
        let x = beta * self.slope * current + self.offset;
        let plus = 1.0 / (1.0 + (-2.0 * x).exp());
        (plus, 1.0 - plus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_tanh() {
        let w = WtaTanh::ideal();
        assert_eq!(w.activate(1.0, 0.0), 0.0);
        assert!((w.activate(1.0, 1.0) - 1f64.tanh()).abs() < 1e-12);
        assert!((w.activate(2.0, 0.5) - 1f64.tanh()).abs() < 1e-12);
    }

    #[test]
    fn branches_subtract_to_tanh() {
        let mut rng = HostRng::new(4);
        let w = WtaTanh::sample(&mut rng, 0.08, 0.03);
        for i in [-2.0, -0.3, 0.0, 0.7, 1.9] {
            let (p, m) = w.fermi_branches(1.3, i);
            assert!((p - m - w.activate(1.3, i)).abs() < 1e-12);
            assert!((0.0..=1.0).contains(&p) && (0.0..=1.0).contains(&m));
        }
    }

    #[test]
    fn saturates() {
        let w = WtaTanh::ideal();
        assert!(w.activate(5.0, 10.0) > 0.999999);
        assert!(w.activate(5.0, -10.0) < -0.999999);
    }

    #[test]
    fn offset_shifts_zero_crossing() {
        let w = WtaTanh { slope: 1.0, offset: 0.1 };
        // activate(-offset/beta·slope) == 0
        assert!(w.activate(1.0, -0.1).abs() < 1e-12);
    }

    #[test]
    fn slope_never_sampled_nonpositive() {
        let mut rng = HostRng::new(5);
        for _ in 0..5000 {
            let w = WtaTanh::sample(&mut rng, 0.5, 0.0);
            assert!(w.slope > 0.0);
        }
    }
}
