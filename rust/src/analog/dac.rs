//! MOS R-2R current-mode DAC (Fig 3 of the paper).
//!
//! The 8-bit weight/bias/RNG DACs are MOS-transistor R-2R ladders chosen
//! for area efficiency. Two non-idealities matter at 1 V supply with no
//! output-resistance enhancement (both called out in the paper):
//!
//! * a per-instance **gain error** — the ladder's output resistance loads
//!   the summing node, scaling the full-scale current;
//! * **INL/DNL** from per-bit element mismatch — each ladder rung's
//!   binary weight deviates from its nominal 2^k ratio.
//!
//! Codes are sign-magnitude like the silicon: bit 7 steers the Gilbert
//! multiplier polarity, bits 6..0 set the magnitude.

use crate::rng::HostRng;

/// Behavioral 8-bit R-2R DAC instance with frozen mismatch.
#[derive(Debug, Clone)]
pub struct R2rDac {
    /// Per-instance gain (nominal 1.0).
    gain: f64,
    /// Effective weight of each magnitude bit (nominal 2^k/127 · fs/?).
    bit_weights: [f64; 7],
}

impl R2rDac {
    /// Draw a DAC instance. `sigma_gain` models the finite-Rout loading,
    /// `sigma_r2r` the per-rung element mismatch.
    pub fn sample(rng: &mut HostRng, sigma_gain: f64, sigma_r2r: f64) -> Self {
        let gain = rng.normal_ms(1.0, sigma_gain);
        // rung k nominally contributes 2^k; element mismatch scales each
        // rung independently (relative sigma grows for the small rungs —
        // fewer unit devices — as 1/sqrt(2^k)).
        let bit_weights = std::array::from_fn(|k| {
            let rel = sigma_r2r / (2f64.powi(k as i32)).sqrt();
            2f64.powi(k as i32) * rng.normal_ms(1.0, rel)
        });
        Self { gain, bit_weights }
    }

    /// An exactly ideal instance.
    pub fn ideal() -> Self {
        Self { gain: 1.0, bit_weights: std::array::from_fn(|k| 2f64.powi(k as i32)) }
    }

    /// Instance gain (used when folding into J_eff).
    pub fn gain(&self) -> f64 {
        self.gain
    }

    /// Convert a signed 8-bit weight code to a normalized output current
    /// in ≈[−1, 1] (full scale = code ±127).
    pub fn convert(&self, code: i8) -> f64 {
        let mag = (code as i32).unsigned_abs().min(127);
        let mut acc = 0.0;
        for k in 0..7 {
            if (mag >> k) & 1 == 1 {
                acc += self.bit_weights[k];
            }
        }
        let current = self.gain * acc / 127.0;
        if code < 0 {
            -current
        } else {
            current
        }
    }

    /// Integral nonlinearity profile: deviation of `convert` from the
    /// ideal straight line, in LSB, over all positive codes.
    pub fn inl(&self) -> Vec<f64> {
        let fs = self.convert(127);
        (0..=127i8)
            .map(|c| {
                let ideal = fs * (c as f64) / 127.0;
                (self.convert(c) - ideal) * 127.0 / fs.abs().max(1e-12)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_linear() {
        let d = R2rDac::ideal();
        assert_eq!(d.convert(0), 0.0);
        assert!((d.convert(127) - 1.0).abs() < 1e-12);
        assert!((d.convert(-127) + 1.0).abs() < 1e-12);
        assert!((d.convert(64) - 64.0 / 127.0).abs() < 1e-12);
        let inl = d.inl();
        assert!(inl.iter().all(|&x| x.abs() < 1e-9));
    }

    #[test]
    fn sign_magnitude_symmetry() {
        let mut rng = HostRng::new(1);
        let d = R2rDac::sample(&mut rng, 0.05, 0.02);
        for c in [1i8, 17, 63, 127] {
            assert_eq!(d.convert(c), -d.convert(-c));
        }
    }

    #[test]
    fn monotonic_in_code_for_small_mismatch() {
        let mut rng = HostRng::new(2);
        for seed in 0..20 {
            let _ = seed;
            let d = R2rDac::sample(&mut rng, 0.05, 0.01);
            let mut prev = f64::NEG_INFINITY;
            for c in 0..=127i8 {
                let v = d.convert(c);
                assert!(v >= prev - 0.02, "non-monotonic at {c}");
                prev = v;
            }
        }
    }

    #[test]
    fn gain_spread_matches_sigma() {
        let mut rng = HostRng::new(3);
        let n = 2000;
        let gains: Vec<f64> = (0..n)
            .map(|_| R2rDac::sample(&mut rng, 0.05, 0.0).convert(127))
            .collect();
        let mean = gains.iter().sum::<f64>() / n as f64;
        let var = gains.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01);
        assert!((var.sqrt() - 0.05).abs() < 0.01, "sigma {}", var.sqrt());
    }

    #[test]
    fn inl_grows_with_mismatch() {
        let mut rng = HostRng::new(4);
        let tight = R2rDac::sample(&mut rng, 0.0, 0.002);
        let loose = R2rDac::sample(&mut rng, 0.0, 0.05);
        let max_inl = |d: &R2rDac| d.inl().iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        assert!(max_inl(&loose) > max_inl(&tight));
    }
}
