//! Behavioral models of the chip's analog standard cells (Figs 3–6).
//!
//! The die shares one 1 V supply between analog and digital and lays the
//! analog blocks out as unmatched, pitch-matched standard cells placed by
//! the digital P&R flow — the paper's central area trick. The price is
//! static per-instance mismatch in every block, which these models carry
//! explicitly and which the hardware-aware CD trainer absorbs.
//!
//! | silicon block | model |
//! |---|---|
//! | MOS R-2R weight/bias/RNG DAC (Fig 3) | [`R2rDac`]: gain error + per-rung INL |
//! | current-mode Gilbert multiplier (Fig 5) | [`GilbertMultiplier`]: gain + static offset |
//! | WTA tanh (Fig 4, Lazzaro '88) | [`WtaTanh`]: slope + input-referred offset |
//! | WTA comparator + self-biased amp (Fig 6) | [`Comparator`]: offset, ties high |
//! | external-resistor bias generator (Fig 6) | [`BiasGenerator`]: 4 global scales |
//!
//! [`Personality`] freezes one die's instances; [`Personality::fold`]
//! lowers programmed codes into the effective tensors every sampler
//! (XLA, software, cycle-level chip) consumes.

mod bias;
mod comparator;
mod dac;
mod mismatch;
mod multiplier;
mod tanh;

pub use bias::BiasGenerator;
pub use comparator::Comparator;
pub use dac::R2rDac;
pub use mismatch::{EdgeCircuits, Folded, Personality, ProgrammedWeights, SpinCircuits};
pub use multiplier::GilbertMultiplier;
pub use tanh::WtaTanh;
