//! WTA comparator + self-biased differential amplifier (Fig 6).
//!
//! The tanh output current and the RNG DAC's random current sum on the
//! comparator input; the comparator resolves the sign into the spin
//! flip-flop. Its input-referred offset adds to the WTA offset (they are
//! merged into one o_β term when folding for the kernels); here it is
//! kept separate so the cycle-level chip model reflects the real
//! signal chain. Ties resolve +1 (the self-biased output stage's skew).

use crate::rng::HostRng;

/// One comparator instance with frozen input-referred offset.
#[derive(Debug, Clone, Copy)]
pub struct Comparator {
    /// Input-referred offset current (nominal 0).
    pub offset: f64,
}

impl Comparator {
    /// Draw one instance from the mismatch corner.
    pub fn sample(rng: &mut HostRng, sigma_offset: f64) -> Self {
        Self { offset: rng.normal_ms(0.0, sigma_offset) }
    }

    /// A perfectly matched instance.
    pub fn ideal() -> Self {
        Self { offset: 0.0 }
    }

    /// Resolve the differential input to a spin.
    #[inline]
    pub fn decide(&self, differential: f64) -> i8 {
        if differential + self.offset >= 0.0 {
            1
        } else {
            -1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_sign() {
        let c = Comparator::ideal();
        assert_eq!(c.decide(0.3), 1);
        assert_eq!(c.decide(-0.3), -1);
        assert_eq!(c.decide(0.0), 1, "ties must resolve high");
    }

    #[test]
    fn offset_biases_decisions() {
        let c = Comparator { offset: 0.2 };
        assert_eq!(c.decide(-0.1), 1);
        assert_eq!(c.decide(-0.3), -1);
    }

    #[test]
    fn sampled_offsets_centered() {
        let mut rng = HostRng::new(6);
        let n = 2000;
        let mean: f64 =
            (0..n).map(|_| Comparator::sample(&mut rng, 0.05).offset).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.005);
    }
}
