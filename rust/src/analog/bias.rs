//! Bias generator (Fig 6): the external-resistor-programmed scale
//! currents that set the relative strength of the coupling weights, the
//! bias weights, the random number DACs and the tanh — the chip's four
//! global knobs. The annealing voltage V_temp maps onto the tanh scale
//! (effective β).

/// Global analog scales, all nominally 1.0 full-scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BiasGenerator {
    /// Coupling-weight DAC full-scale (I_J).
    pub coupling_scale: f64,
    /// Bias-weight DAC full-scale (I_h).
    pub bias_scale: f64,
    /// RNG DAC full-scale (I_rand).
    pub rng_scale: f64,
    /// tanh gain — the electrical image of β / V_temp.
    pub tanh_scale: f64,
}

impl Default for BiasGenerator {
    fn default() -> Self {
        Self { coupling_scale: 1.0, bias_scale: 1.0, rng_scale: 1.0, tanh_scale: 1.0 }
    }
}

impl BiasGenerator {
    /// Configure for a given inverse temperature: the chip implements
    /// annealing by raising V_temp, which scales the tanh stage.
    pub fn with_beta(beta: f64) -> Self {
        Self { tanh_scale: beta, ..Self::default() }
    }

    /// Effective β seen by the p-bit update.
    pub fn beta(&self) -> f64 {
        self.tanh_scale
    }

    /// Ratio of random current to coupling current — controls how
    /// stochastic the update is at fixed β (an ablation knob).
    pub fn noise_ratio(&self) -> f64 {
        self.rng_scale / self.coupling_scale.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_unity() {
        let b = BiasGenerator::default();
        assert_eq!(b.beta(), 1.0);
        assert_eq!(b.noise_ratio(), 1.0);
    }

    #[test]
    fn beta_knob() {
        let b = BiasGenerator::with_beta(3.5);
        assert_eq!(b.beta(), 3.5);
        assert_eq!(b.coupling_scale, 1.0);
    }
}
