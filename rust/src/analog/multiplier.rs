//! Current-mode Gilbert multiplier (Fig 5 of the paper).
//!
//! Implements eqn (1)'s weight × spin product: the weight DAC's current
//! is steered by the spin's differential voltage, so the output is
//! `±I_weight` in differential form (which is how bipolar weights come
//! for free). The unmatched standard-cell layout gives each instance a
//! static **gain error** and a static **offset current** that flows into
//! the summing node regardless of the spin — the paper's motivation for
//! learning *through* the hardware.

use crate::rng::HostRng;

/// One Gilbert multiplier instance with frozen mismatch.
#[derive(Debug, Clone, Copy)]
pub struct GilbertMultiplier {
    /// Multiplicative gain (nominal 1).
    pub gain: f64,
    /// Static differential offset current, in full-scale weight units.
    pub offset: f64,
}

impl GilbertMultiplier {
    /// Draw one instance from the mismatch corner.
    pub fn sample(rng: &mut HostRng, sigma_gain: f64, sigma_offset: f64) -> Self {
        Self {
            gain: rng.normal_ms(1.0, sigma_gain),
            offset: rng.normal_ms(0.0, sigma_offset),
        }
    }

    /// A perfectly matched instance.
    pub fn ideal() -> Self {
        Self { gain: 1.0, offset: 0.0 }
    }

    /// Multiply a weight current by a spin (±1), returning the output
    /// current including the instance offset.
    #[inline]
    pub fn multiply(&self, weight_current: f64, spin: i8) -> f64 {
        debug_assert!(spin == 1 || spin == -1);
        self.gain * weight_current * spin as f64 + self.offset
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_multiplies_exactly() {
        let m = GilbertMultiplier::ideal();
        assert_eq!(m.multiply(0.5, 1), 0.5);
        assert_eq!(m.multiply(0.5, -1), -0.5);
        assert_eq!(m.multiply(0.0, -1), 0.0);
    }

    #[test]
    fn offset_is_spin_independent() {
        let m = GilbertMultiplier { gain: 1.0, offset: 0.03 };
        let up = m.multiply(0.2, 1);
        let dn = m.multiply(0.2, -1);
        // offset shifts both branches the same way
        assert!((up + dn - 2.0 * 0.03).abs() < 1e-12);
    }

    #[test]
    fn gain_scales_product() {
        let m = GilbertMultiplier { gain: 1.1, offset: 0.0 };
        assert!((m.multiply(0.5, -1) + 0.55).abs() < 1e-12);
    }

    #[test]
    fn sample_statistics() {
        let mut rng = HostRng::new(10);
        let n = 3000;
        let insts: Vec<_> =
            (0..n).map(|_| GilbertMultiplier::sample(&mut rng, 0.04, 0.02)).collect();
        let gmean = insts.iter().map(|m| m.gain).sum::<f64>() / n as f64;
        let omean = insts.iter().map(|m| m.offset).sum::<f64>() / n as f64;
        assert!((gmean - 1.0).abs() < 0.01);
        assert!(omean.abs() < 0.01);
        let gsd = (insts.iter().map(|m| (m.gain - gmean).powi(2)).sum::<f64>() / n as f64).sqrt();
        assert!((gsd - 0.04).abs() < 0.01);
    }
}
