//! Per-chip process-variation personality and the fold into kernel
//! tensors (DESIGN.md §5).
//!
//! A [`Personality`] freezes every analog instance on one simulated die:
//! one weight DAC per undirected coupler (the current is converted to a
//! bias voltage and distributed, so both directions share it), one
//! Gilbert multiplier **per direction** (each node owns its input
//! multipliers, so J_eff is slightly asymmetric — a real consequence of
//! the standard-cell methodology), and per p-bit bias DAC / WTA tanh /
//! comparator.
//!
//! [`Personality::fold`] lowers programmed register codes into the four
//! effective tensors the L1 kernel consumes (`jt_eff`, `h_eff`, `g`,
//! `o`); the cycle-level chip simulator uses the *same* folded values, so
//! the XLA sampler and the chip agree bit-for-bit given the same uniform
//! randoms (modulo f32 tanh ulps — see `rust/tests/chip_vs_xla.rs`).

use crate::chimera::{Topology, N_PAD, N_SPINS};
use crate::config::MismatchConfig;
use crate::rng::HostRng;

use super::comparator::Comparator;
use super::dac::R2rDac;
use super::multiplier::GilbertMultiplier;
use super::tanh::WtaTanh;

/// Analog instances hanging off one undirected coupler (i < j).
#[derive(Debug, Clone)]
pub struct EdgeCircuits {
    /// Shared weight DAC (one per coupler to save area).
    pub dac: R2rDac,
    /// Multiplier on node i's summing wire (input from m_j).
    pub mul_into_i: GilbertMultiplier,
    /// Multiplier on node j's summing wire (input from m_i).
    pub mul_into_j: GilbertMultiplier,
}

/// Analog instances of one p-bit.
#[derive(Debug, Clone)]
pub struct SpinCircuits {
    /// The p-bit's bias-current DAC.
    pub bias_dac: R2rDac,
    /// The p-bit's WTA tanh stage.
    pub wta: WtaTanh,
    /// The p-bit's decision comparator.
    pub comparator: Comparator,
}

/// Register state the personality folds (owned by [`crate::spi::RegMap`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgrammedWeights {
    /// 8-bit coupling code per canonical edge (same order as
    /// `Topology::edges`).
    pub j_codes: Vec<i8>,
    /// Enable bit per canonical edge.
    pub enables: Vec<bool>,
    /// 8-bit bias code per spin.
    pub h_codes: Vec<i8>,
}

impl ProgrammedWeights {
    /// All-zero (everything disabled) register image.
    pub fn zeros(n_edges: usize) -> Self {
        Self { j_codes: vec![0; n_edges], enables: vec![false; n_edges], h_codes: vec![0; N_SPINS] }
    }
}

/// Effective tensors ready for the L1 kernel / chip hot loop.
#[derive(Debug, Clone)]
pub struct Folded {
    /// `[N_PAD * N_PAD]` row-major, laid out transposed: entry
    /// `[j * N_PAD + i]` is the current into p-bit i from spin j, so the
    /// kernel's `I = m @ jt_eff` works directly.
    pub jt_eff: Vec<f32>,
    /// `[N_PAD]` effective bias current (bias DAC + multiplier offsets).
    pub h_eff: Vec<f32>,
    /// `[N_PAD]` tanh slope mismatch.
    pub g: Vec<f32>,
    /// `[N_PAD]` input-referred offset.
    pub o: Vec<f32>,
}

impl Folded {
    /// Current into p-bit `i` from spin `j`.
    #[inline]
    pub fn j_eff(&self, i: usize, j: usize) -> f32 {
        self.jt_eff[j * N_PAD + i]
    }
}

/// One simulated die's frozen mismatch.
#[derive(Debug, Clone)]
pub struct Personality {
    /// Seed the die was drawn with.
    pub seed: u64,
    /// Mismatch corner the draws used.
    pub cfg: MismatchConfig,
    /// Per-coupler analog instances (canonical edge order).
    pub edges: Vec<EdgeCircuits>,
    /// Per-p-bit analog instances (spin order).
    pub spins: Vec<SpinCircuits>,
}

impl Personality {
    /// Draw a die. The per-instance draws consume the RNG in a fixed
    /// order, so (seed, cfg) fully determines the personality.
    pub fn sample(topo: &Topology, seed: u64, cfg: MismatchConfig) -> Self {
        let mut rng = HostRng::new(seed ^ 0xC41B_5EED_0000_0000);
        let edges = topo
            .edges
            .iter()
            .map(|_| EdgeCircuits {
                dac: R2rDac::sample(&mut rng, cfg.sigma_dac, cfg.sigma_r2r),
                mul_into_i: GilbertMultiplier::sample(&mut rng, cfg.sigma_mul, cfg.sigma_off),
                mul_into_j: GilbertMultiplier::sample(&mut rng, cfg.sigma_mul, cfg.sigma_off),
            })
            .collect();
        let spins = (0..N_SPINS)
            .map(|_| SpinCircuits {
                bias_dac: R2rDac::sample(&mut rng, cfg.sigma_dac, cfg.sigma_r2r),
                // the comparator's input-referred offset is folded into
                // the WTA offset term (one o_β per p-bit) so the kernel
                // and the cycle-level chip share one signal-chain model.
                wta: WtaTanh::sample(&mut rng, cfg.sigma_beta, cfg.sigma_obeta),
                comparator: Comparator::ideal(),
            })
            .collect();
        Self { seed, cfg, edges, spins }
    }

    /// An exactly ideal die (software-baseline corner).
    pub fn ideal(topo: &Topology) -> Self {
        Self {
            seed: 0,
            cfg: MismatchConfig::ideal(),
            edges: topo
                .edges
                .iter()
                .map(|_| EdgeCircuits {
                    dac: R2rDac::ideal(),
                    mul_into_i: GilbertMultiplier::ideal(),
                    mul_into_j: GilbertMultiplier::ideal(),
                })
                .collect(),
            spins: (0..N_SPINS)
                .map(|_| SpinCircuits {
                    bias_dac: R2rDac::ideal(),
                    wta: WtaTanh::ideal(),
                    comparator: Comparator::ideal(),
                })
                .collect(),
        }
    }

    /// Lower programmed codes through the analog models into effective
    /// kernel tensors. Weight codes are normalized so code 127 ≙ 1.0.
    pub fn fold(&self, topo: &Topology, w: &ProgrammedWeights) -> Folded {
        assert_eq!(w.j_codes.len(), topo.edges.len());
        assert_eq!(w.enables.len(), topo.edges.len());
        let mut jt_eff = vec![0.0f32; N_PAD * N_PAD];
        let mut h_eff = vec![0.0f32; N_PAD];
        let mut g = vec![0.0f32; N_PAD];
        let mut o = vec![0.0f32; N_PAD];

        for (e, &(i, j)) in topo.edges.iter().enumerate() {
            let ckt = &self.edges[e];
            let weight_current = ckt.dac.convert(w.j_codes[e]);
            // A disabled coupler still leaks `leak` of its current and
            // offset — the very reason the enable bit exists (paper).
            let scale = if w.enables[e] { 1.0 } else { self.cfg.leak };
            // current into i from m_j: multiplier gain × weight; the
            // static offset flows into i's node regardless of m_j.
            let into_i = scale * ckt.mul_into_i.gain * weight_current;
            let into_j = scale * ckt.mul_into_j.gain * weight_current;
            jt_eff[j * N_PAD + i] = into_i as f32;
            jt_eff[i * N_PAD + j] = into_j as f32;
            h_eff[i] += (scale * ckt.mul_into_i.offset) as f32;
            h_eff[j] += (scale * ckt.mul_into_j.offset) as f32;
        }
        for (s, ckt) in self.spins.iter().enumerate() {
            h_eff[s] += ckt.bias_dac.convert(w.h_codes[s]) as f32;
            g[s] = ckt.wta.slope as f32;
            o[s] = ckt.wta.offset as f32;
        }
        // padding lanes: g = 1 keeps tanh well-defined, everything else 0.
        for s in N_SPINS..N_PAD {
            g[s] = 1.0;
        }
        Folded { jt_eff, h_eff, g, o }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new()
    }

    fn all_on(topo: &Topology, code: i8) -> ProgrammedWeights {
        ProgrammedWeights {
            j_codes: vec![code; topo.edges.len()],
            enables: vec![true; topo.edges.len()],
            h_codes: vec![0; N_SPINS],
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let t = topo();
        let a = Personality::sample(&t, 7, MismatchConfig::default());
        let b = Personality::sample(&t, 7, MismatchConfig::default());
        assert_eq!(a.spins[13].wta.slope, b.spins[13].wta.slope);
        assert_eq!(a.edges[100].dac.convert(55), b.edges[100].dac.convert(55));
        let c = Personality::sample(&t, 8, MismatchConfig::default());
        assert_ne!(a.spins[13].wta.slope, c.spins[13].wta.slope);
    }

    #[test]
    fn ideal_fold_reproduces_codes() {
        let t = topo();
        let p = Personality::ideal(&t);
        let w = all_on(&t, 127);
        let f = p.fold(&t, &w);
        for &(i, j) in t.edges.iter().take(50) {
            assert!((f.j_eff(i, j) - 1.0).abs() < 1e-6);
            assert!((f.j_eff(j, i) - 1.0).abs() < 1e-6);
        }
        assert!(f.h_eff.iter().all(|&x| x == 0.0));
        assert!(f.g[..N_SPINS].iter().all(|&x| x == 1.0));
        assert!(f.o.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fold_zeroes_non_edges_and_padding() {
        let t = topo();
        let p = Personality::sample(&t, 3, MismatchConfig::default());
        let f = p.fold(&t, &all_on(&t, 64));
        // vertical spins 0 and 1 of cell 0 are not coupled
        assert_eq!(f.j_eff(0, 1), 0.0);
        for pad in N_SPINS..N_PAD {
            for s in 0..N_PAD {
                assert_eq!(f.j_eff(pad, s), 0.0);
                assert_eq!(f.j_eff(s, pad), 0.0);
            }
            assert_eq!(f.h_eff[pad], 0.0);
        }
    }

    #[test]
    fn asymmetry_from_per_direction_multipliers() {
        let t = topo();
        let p = Personality::sample(&t, 11, MismatchConfig::default());
        let f = p.fold(&t, &all_on(&t, 127));
        let mut asym = 0usize;
        for &(i, j) in &t.edges {
            if (f.j_eff(i, j) - f.j_eff(j, i)).abs() > 1e-6 {
                asym += 1;
            }
        }
        // essentially every coupler should differ between directions
        assert!(asym > t.edges.len() * 9 / 10, "only {asym} asymmetric");
    }

    #[test]
    fn disabled_coupler_leaks() {
        let t = topo();
        let cfg = MismatchConfig { leak: 0.1, ..MismatchConfig::default() };
        let p = Personality::sample(&t, 5, cfg);
        let mut w = all_on(&t, 127);
        let f_on = p.fold(&t, &w);
        w.enables[0] = false;
        let f_off = p.fold(&t, &w);
        let (i, j) = t.edges[0];
        let ratio = f_off.j_eff(i, j) / f_on.j_eff(i, j);
        assert!((ratio - 0.1).abs() < 1e-5, "leak ratio {ratio}");
    }

    #[test]
    fn offsets_accumulate_on_bias() {
        let t = topo();
        let cfg = MismatchConfig { sigma_off: 0.05, ..MismatchConfig::default() };
        let p = Personality::sample(&t, 9, cfg);
        let f = p.fold(&t, &all_on(&t, 0));
        // with all codes zero, h_eff is purely multiplier offsets — most
        // spins should see a nonzero static current.
        let nonzero = f.h_eff[..N_SPINS].iter().filter(|&&x| x != 0.0).count();
        assert!(nonzero > N_SPINS * 9 / 10);
    }

    #[test]
    fn ideal_mismatchless_offsets_zero() {
        let t = topo();
        let p = Personality::ideal(&t);
        let f = p.fold(&t, &all_on(&t, 0));
        assert!(f.h_eff.iter().all(|&x| x == 0.0));
        assert!(f.jt_eff.iter().all(|&x| x == 0.0));
    }
}
