//! Paper-evaluation experiments, one function per table/figure.
//!
//! Examples, benches and the CLI all call into here so every artifact is
//! regenerated from a single implementation:
//!
//! | paper artifact | function |
//! |---|---|
//! | Fig 7b/7c AND-gate CD learning | [`fig7_gate_learning`] |
//! | Fig 8a bias-sweep variability | [`fig8a_bias_sweep`] |
//! | Fig 8b full-adder distribution | [`fig8b_adder_learning`] |
//! | Fig 9a SK-glass annealing | [`fig9a_sk_anneal`] |
//! | Fig 9b Max-Cut | [`fig9b_maxcut`] |
//! | Table 1 TTS / throughput | [`table1_tts`] |

pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;

pub use fig7::{fig7_gate_learning, GateExperiment, GateReport};
pub use fig8::{fig8a_bias_sweep, fig8b_adder_learning, BiasSweepReport};
pub use fig9::{fig9a_sk_anneal, fig9b_maxcut, MaxCutReport, SkAnnealReport};
pub use table1::{table1_tts, Table1Report};

use crate::config::MismatchConfig;
use crate::learning::Hw;
use crate::sampler::SoftwareSampler;

/// Which engine an experiment drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust CSR sampler (fast default).
    Software,
    /// AOT PJRT path (requires `make artifacts`).
    Xla,
}

/// Build a software-engine chip with the given mismatch corner.
pub fn software_chip(seed: u64, cfg: MismatchConfig, batch: usize) -> Hw<SoftwareSampler> {
    let topo = crate::chimera::Topology::new();
    let personality = crate::analog::Personality::sample(&topo, seed, cfg);
    Hw::new(SoftwareSampler::new(batch, seed), personality)
}

/// Build an ideal (mismatch-free) software chip.
pub fn ideal_chip(seed: u64, batch: usize) -> Hw<SoftwareSampler> {
    let topo = crate::chimera::Topology::new();
    Hw::new(SoftwareSampler::new(batch, seed), crate::analog::Personality::ideal(&topo))
}
