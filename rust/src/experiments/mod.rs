//! Paper-evaluation experiments, one function per table/figure.
//!
//! Examples, benches and the CLI all call into here so every artifact is
//! regenerated from a single implementation:
//!
//! | paper artifact | function |
//! |---|---|
//! | Fig 7b/7c AND-gate CD learning | [`fig7_gate_learning`] |
//! | Fig 8a bias-sweep variability | [`fig8a_bias_sweep`] |
//! | Fig 8b full-adder distribution | [`fig8b_adder_learning`] |
//! | Fig 9a SK-glass annealing | [`fig9a_sk_anneal`] |
//! | Fig 9b Max-Cut | [`fig9b_maxcut`] |
//! | Table 1 TTS / throughput | [`table1_tts`] |

pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;

pub use fig7::{fig7_gate_learning, GateExperiment, GateReport};
pub use fig8::{fig8a_bias_sweep, fig8b_adder_learning, BiasSweepReport};
pub use fig9::{
    fig9a_sk_anneal, fig9a_sk_ladder_tuning, fig9a_sk_temper_sharded, fig9a_sk_temper_vs_anneal,
    fig9b_maxcut, MaxCutReport, ShardedSkReport, SkAnnealReport, TemperVsAnnealReport,
    TunedLadderReport,
};
pub use table1::{
    table1_tts, table1_tts_sharded, table1_tts_tempering, table1_tts_tuned, ShardedTtsReport,
    Table1Report, TunedTtsReport,
};

use anyhow::Result;

use crate::analog::ProgrammedWeights;
use crate::chimera::Topology;
use crate::config::MismatchConfig;
use crate::learning::{Hw, TrainableChip};
use crate::problems::IsingProblem;
use crate::sampler::SoftwareSampler;

/// Which engine an experiment drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust CSR sampler (fast default).
    Software,
    /// AOT PJRT path (requires `make artifacts`).
    Xla,
}

/// Build a software-engine chip with the given mismatch corner.
pub fn software_chip(seed: u64, cfg: MismatchConfig, batch: usize) -> Hw<SoftwareSampler> {
    let topo = crate::chimera::Topology::new();
    let personality = crate::analog::Personality::sample(&topo, seed, cfg);
    Hw::new(SoftwareSampler::new(batch, seed), personality)
}

/// Build an ideal (mismatch-free) software chip.
pub fn ideal_chip(seed: u64, batch: usize) -> Hw<SoftwareSampler> {
    let topo = crate::chimera::Topology::new();
    Hw::new(SoftwareSampler::new(batch, seed), crate::analog::Personality::ideal(&topo))
}

/// Build the die array for a sharded tempering run: one software chip
/// per shard of `params.base.ladder`, each programmed with `problem`
/// and sized `die_batch` (or its rung count, whichever is larger).
///
/// Die seeds step by 0x1000 from `seed_base`. (The LFSR noise banks now
/// splitmix-hash every chain ≥ 1's seed, so cross-die aliasing is no
/// longer possible; the stride is kept so each die's chain-0
/// chip-fidelity bank stays distinct and recorded runs replay.)
/// `randomize_seed(shard)` seeds each die's starting states. Returns
/// the chips in shard (rung) order plus the shared code→logical scale.
pub fn sharded_die_array(
    params: &crate::coordinator::ShardedTemperingParams,
    problem: &IsingProblem,
    mcfg: MismatchConfig,
    die_batch: usize,
    seed_base: u64,
    randomize_seed: impl Fn(usize) -> u64,
) -> Result<(Vec<Hw<SoftwareSampler>>, f64)> {
    let topo = Topology::new();
    let rungs = params.base.ladder.len();
    anyhow::ensure!(
        params.shards >= 1 && params.shards <= rungs,
        "need between 1 and {rungs} shards, got {}",
        params.shards
    );
    let ranges = params.base.ladder.partition(params.shards);
    let mut chips = Vec::with_capacity(params.shards);
    let mut scale = 1.0;
    for (s, range) in ranges.iter().enumerate() {
        let die_seed = seed_base + 0x1000 * (s as u64 + 1);
        let mut chip = software_chip(die_seed, mcfg, die_batch.max(range.len()));
        scale = program_problem(&mut chip, &topo, problem)?;
        crate::sampler::Sampler::randomize(&mut chip, randomize_seed(s));
        chips.push(chip);
    }
    Ok((chips, scale))
}

/// Lower `problem` to 8-bit register codes and program it onto `chip`.
/// Returns the code → logical scale (β_chip = β_logical × scale) —
/// the one lowering block every experiment shares.
pub fn program_problem<C: TrainableChip>(
    chip: &mut C,
    topo: &Topology,
    problem: &IsingProblem,
) -> Result<f64> {
    let (j_codes, enables, h_codes, scale) = problem.to_codes(topo)?;
    chip.program_codes(&ProgrammedWeights { j_codes, enables, h_codes })?;
    Ok(scale)
}
