//! Fig 7: in-situ CD learning of a logic gate on a mismatched die.
//!
//! 7b — the measured visible-state distribution sharpening onto the four
//! valid AND rows as learning proceeds; 7c — the data−model correlation
//! gap converging to zero.

use anyhow::Result;

use crate::chimera::{and_gate_layout, GateLayout};
use crate::config::MismatchConfig;
use crate::learning::dataset::{self, Dataset};
use crate::learning::{CdParams, CdTrainer, EpochStats, TrainableChip};
use crate::util::bench::write_csv;

/// Experiment configuration.
#[derive(Debug, Clone)]
pub struct GateExperiment {
    /// Where the gate sits on the die.
    pub layout: GateLayout,
    /// The truth table to learn.
    pub dataset: Dataset,
    /// Trainer hyperparameters.
    pub params: CdParams,
    /// Mismatch corner of the die under test.
    pub mismatch: MismatchConfig,
    /// Personality seed of the die under test.
    pub chip_seed: u64,
    /// Distribution snapshots at these epochs (Fig 7b panels).
    pub snapshot_epochs: Vec<usize>,
    /// Samples per distribution evaluation.
    pub eval_samples: usize,
}

impl GateExperiment {
    /// The paper's AND-gate run on the default mismatch corner.
    pub fn and_default() -> Self {
        Self {
            layout: and_gate_layout(0, 0),
            dataset: dataset::and_gate(),
            params: CdParams::default(),
            mismatch: MismatchConfig::default(),
            chip_seed: 7,
            snapshot_epochs: vec![0, 10, 40, 149],
            eval_samples: 4000,
        }
    }
}

/// Everything Fig 7 plots.
#[derive(Debug, Clone)]
pub struct GateReport {
    /// Epoch series: (epoch, kl, corr_gap, valid_mass) — Fig 7c.
    pub epochs: Vec<EpochStats>,
    /// (epoch, distribution over 2^k visible states) — Fig 7b panels.
    pub snapshots: Vec<(usize, Vec<f64>)>,
    /// Target (truth-table) distribution.
    pub target: Vec<f64>,
    /// KL(target ‖ model) after the last epoch.
    pub final_kl: f64,
    /// Probability mass on valid truth-table states after training.
    pub final_valid_mass: f64,
}

/// Run CD learning of a gate through the given chip.
pub fn fig7_gate_learning<C: TrainableChip>(
    exp: &GateExperiment,
    chip: &mut C,
    csv_name: Option<&str>,
) -> Result<GateReport> {
    let mut trainer = CdTrainer::new(exp.layout.clone(), exp.dataset.clone(), exp.params);
    chip.program_codes(&trainer.codes)?;
    chip.set_beta(exp.params.beta as f32);

    let mut epochs = Vec::new();
    let mut snapshots = Vec::new();
    for epoch in 0..exp.params.epochs {
        let gap = trainer.epoch(chip)?;
        let want_snapshot = exp.snapshot_epochs.contains(&epoch);
        let want_eval = epoch % 5 == 0 || epoch == exp.params.epochs - 1 || want_snapshot;
        if want_eval {
            let hist = trainer.visible_histogram(chip, exp.eval_samples)?;
            let p_model = hist.probabilities();
            let target = exp.dataset.target_distribution();
            let kl = crate::metrics::kl_divergence(&target, &p_model, 1e-4);
            let valid: f64 = target
                .iter()
                .zip(&p_model)
                .filter(|&(&t, _)| t > 0.0)
                .map(|(_, &m)| m)
                .sum();
            epochs.push(EpochStats::new(epoch, kl, gap, valid));
            if want_snapshot {
                snapshots.push((epoch, p_model));
            }
        }
    }
    let target = exp.dataset.target_distribution();
    let last = epochs.last().cloned().expect("at least one eval");
    if let Some(name) = csv_name {
        let rows: Vec<Vec<f64>> = epochs
            .iter()
            .map(|e| vec![e.epoch as f64, e.kl, e.corr_gap, e.valid_mass])
            .collect();
        write_csv(name, "epoch,kl,corr_gap,valid_mass", &rows)?;
    }
    Ok(GateReport {
        epochs,
        snapshots,
        target,
        final_kl: last.kl,
        final_valid_mass: last.valid_mass,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::software_chip;

    #[test]
    fn small_budget_and_run_improves() {
        let mut exp = GateExperiment::and_default();
        exp.params.epochs = 16;
        exp.params.lr = 0.15;
        exp.params.samples_per_pattern = 10;
        exp.params.k_sweeps = 3;
        exp.snapshot_epochs = vec![0, 15];
        exp.eval_samples = 800;
        let mut chip = software_chip(exp.chip_seed, exp.mismatch, 8);
        let report = fig7_gate_learning(&exp, &mut chip, None).unwrap();
        assert_eq!(report.snapshots.len(), 2);
        let first = report.epochs.first().unwrap();
        let last = report.epochs.last().unwrap();
        assert!(
            last.valid_mass > first.valid_mass,
            "valid mass should grow: {} → {}",
            first.valid_mass,
            last.valid_mass
        );
        assert!((report.target.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }
}
