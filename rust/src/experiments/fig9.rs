//! Fig 9a — simulated annealing of a 440-spin Chimera spin glass
//! (energy falls as V_temp ramps); Fig 9b — Max-Cut on the chip vs
//! greedy / exact baselines.

use anyhow::Result;

use crate::annealing::{
    anneal, temper, tune_ladder, AnnealParams, BetaLadder, BetaSchedule, LadderTuning,
    TemperingParams, TemperingRun, TunedLadder, TunerParams,
};
use crate::chimera::Topology;
use crate::config::MismatchConfig;
use crate::coordinator::{run_sharded_tempering, ShardedRun, ShardedTemperingParams};
use crate::learning::TrainableChip;
use crate::metrics::EnergyTrace;
use crate::problems::{maxcut::Graph, sk, IsingProblem};
use crate::sampler::Sampler;
use crate::util::bench::{write_csv, write_csv_text};

/// Fig 9a output.
#[derive(Debug, Clone)]
pub struct SkAnnealReport {
    /// The recorded (sweep, β, mean E, min E) series.
    pub trace: EnergyTrace,
    /// Best energy over every chain and step.
    pub best_energy: f64,
    /// Energy of the all-up state (the "random start" reference level).
    pub initial_energy_scale: f64,
    /// For ±J glasses: −n_edges is a lower bound on the energy.
    pub energy_lower_bound: f64,
}

/// Run the Fig 9a experiment on the given chip.
pub fn fig9a_sk_anneal<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    params: &AnnealParams,
    csv_name: Option<&str>,
) -> Result<SkAnnealReport> {
    let topo = Topology::new();
    let problem = sk::chimera_pm_j(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;
    chip.randomize(seed ^ 0xA55A);
    let (trace, best) = anneal(chip, &problem, params, scale)?;
    let best_energy =
        best.iter().map(|(e, _)| *e).fold(f64::INFINITY, f64::min);
    if let Some(name) = csv_name {
        write_csv_text(name, "sweep,beta,mean_energy,min_energy", &trace.csv_rows())?;
    }
    Ok(SkAnnealReport {
        best_energy,
        initial_energy_scale: 0.0,
        energy_lower_bound: -(topo.edges.len() as f64),
        trace,
    })
}

/// Fig 9b output.
#[derive(Debug, Clone)]
pub struct MaxCutReport {
    /// (sweep, best cut so far) series for the chip.
    pub chip_cut_trace: Vec<(u64, f64)>,
    /// Best cut the chip reached.
    pub chip_best_cut: f64,
    /// Multi-start greedy baseline.
    pub greedy_cut: f64,
    /// Exact optimum when the instance is small enough.
    pub exact_cut: Option<f64>,
    /// Total edge weight W (the cut's upper bound).
    pub total_weight: f64,
    /// Edge count of the instance.
    pub n_edges: usize,
}

/// Run Max-Cut on a native-Chimera instance (the hardware-realistic
/// workload) and compare against baselines.
pub fn fig9b_maxcut<C: TrainableChip>(
    chip: &mut C,
    graph: &Graph,
    problem: &IsingProblem,
    params: &AnnealParams,
    unembed: Option<&crate::chimera::Embedding>,
    csv_name: Option<&str>,
) -> Result<MaxCutReport> {
    let topo = Topology::new();
    let scale = super::program_problem(chip, &topo, problem)?;
    chip.randomize(0xCA7);

    // annealing loop with cut tracking
    let mut best_cut = 0.0f64;
    let mut trace = Vec::new();
    let mut sweeps_done = 0u64;
    for k in 0..params.steps {
        let beta_logical = params.schedule.beta_at(k, params.steps);
        chip.set_beta((beta_logical * scale) as f32);
        chip.sweeps(params.sweeps_per_step)?;
        sweeps_done += params.sweeps_per_step as u64;
        for st in chip.states() {
            let cut = match unembed {
                Some(emb) => {
                    let logical = emb.unembed(&st);
                    graph.cut_value(&logical)
                }
                None => graph.cut_value(&st),
            };
            best_cut = best_cut.max(cut);
        }
        trace.push((sweeps_done, best_cut));
    }

    let (greedy_cut, _) = graph.greedy_baseline(50, 99);
    let exact_cut = if graph.n <= 20 { Some(graph.exact_max_cut()?) } else { None };
    if let Some(name) = csv_name {
        let rows: Vec<Vec<f64>> =
            trace.iter().map(|&(s, c)| vec![s as f64, c, greedy_cut]).collect();
        write_csv(name, "sweep,chip_best_cut,greedy_cut", &rows)?;
    }
    Ok(MaxCutReport {
        chip_cut_trace: trace,
        chip_best_cut: best_cut,
        greedy_cut,
        exact_cut,
        total_weight: graph.total_weight(),
        n_edges: graph.edges.len(),
    })
}

/// Default Fig 9a schedule (geometric V_temp ramp).
pub fn default_sk_params() -> AnnealParams {
    AnnealParams {
        schedule: BetaSchedule::Geometric { b0: 0.08, b1: 4.0 },
        steps: 96,
        sweeps_per_step: 8,
        record_every: 1,
    }
}

/// Default tempering setup matching [`default_sk_params`]'s per-replica
/// sweep budget (96 × 8 = 768 sweeps) and β span, so the two modes are
/// directly comparable on the same die.
pub fn default_sk_temper_params() -> TemperingParams {
    TemperingParams {
        ladder: BetaLadder::geometric(0.08, 4.0, 8),
        sweeps_per_round: 8,
        rounds: 96,
        record_every: 1,
        seed: 0x9A77,
        ..Default::default()
    }
}

/// Default tuner setup for the Fig 9a instance: feedback over the same
/// β span as [`default_sk_temper_params`], measurement bursts of 48
/// rounds × 8 sweeps.
pub fn default_sk_tuner_params() -> TunerParams {
    TunerParams {
        base: TemperingParams {
            ladder: BetaLadder::geometric(0.08, 4.0, 8),
            sweeps_per_round: 8,
            rounds: 48,
            record_every: 8,
            seed: 0x9A77,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Head-to-head: single-replica annealing vs replica exchange on the
/// same instance and die with equal per-replica sweep budgets.
#[derive(Debug, Clone)]
pub struct TemperVsAnnealReport {
    /// The single-replica annealing arm.
    pub anneal: SkAnnealReport,
    /// The replica-exchange arm.
    pub temper: TemperingRun,
    /// The comparison target: the best energy the anneal reached.
    pub target_energy: f64,
    /// Per-replica sweeps each mode needed to first reach the target
    /// (`None` = never within budget).
    pub anneal_sweeps_to_target: Option<u64>,
    /// Tempering's sweeps-to-target (see `anneal_sweeps_to_target`).
    pub temper_sweeps_to_target: Option<u64>,
}

/// First sweep count at which the trace's running minimum reaches
/// `target` (within a small whisker).
pub fn sweeps_to_reach(trace: &EnergyTrace, target: f64) -> Option<u64> {
    let mut best = f64::INFINITY;
    for &(sweep, _, _, min_e) in &trace.rows {
        best = best.min(min_e);
        if best <= target + 1e-9 {
            return Some(sweep);
        }
    }
    None
}

/// Run the Fig 9a instance through both sampling modes. The anneal's
/// best energy becomes the target; the report says how many sweeps each
/// mode needed to get there (`benches/fig9a_sk.rs` prints the table).
pub fn fig9a_sk_temper_vs_anneal<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    anneal_params: &AnnealParams,
    temper_params: &TemperingParams,
    csv_name: Option<&str>,
) -> Result<TemperVsAnnealReport> {
    let topo = Topology::new();
    let problem = sk::chimera_pm_j(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;

    chip.randomize(seed ^ 0xA55A);
    let (a_trace, a_best) = anneal(chip, &problem, anneal_params, scale)?;
    let anneal_best = a_best.iter().map(|(e, _)| *e).fold(f64::INFINITY, f64::min);

    chip.randomize(seed ^ 0x7E39);
    let run = temper(chip, &problem, temper_params, scale)?;
    // tempering leaves per-chain βs pinned; restore a uniform knob for
    // whatever runs on this die next
    chip.set_beta(1.0);

    let target = anneal_best;
    let anneal_report = SkAnnealReport {
        best_energy: anneal_best,
        initial_energy_scale: 0.0,
        energy_lower_bound: -(topo.edges.len() as f64),
        trace: a_trace,
    };
    let report = TemperVsAnnealReport {
        anneal_sweeps_to_target: sweeps_to_reach(&anneal_report.trace, target),
        temper_sweeps_to_target: sweeps_to_reach(&run.trace, target),
        anneal: anneal_report,
        temper: run,
        target_energy: target,
    };
    if let Some(name) = csv_name {
        write_csv_text(
            &format!("{name}_anneal"),
            "sweep,beta,mean_energy,min_energy",
            &report.anneal.trace.csv_rows(),
        )?;
        write_csv_text(
            &format!("{name}_temper"),
            "sweep,beta,mean_energy,min_energy",
            &report.temper.trace.csv_rows(),
        )?;
    }
    Ok(report)
}

/// The Fig 9a tuning extension: a flux-tuned ladder vs the geometric
/// baseline at the same K and sweep budget.
#[derive(Debug, Clone)]
pub struct TunedLadderReport {
    /// The tuner's output (ladder, convergence, diagnostics trail).
    pub tuned: TunedLadder,
    /// Evaluation run on the tuned ladder.
    pub tuned_run: TemperingRun,
    /// Evaluation run on a geometric ladder with the *same K* and β
    /// span — the fair baseline.
    pub geometric_run: TemperingRun,
}

impl TunedLadderReport {
    /// Round trips per replica-sweep of the tuned-ladder evaluation.
    pub fn tuned_round_trips_per_sweep(&self) -> f64 {
        self.tuned_run.round_trips_per_sweep()
    }

    /// Round trips per replica-sweep of the geometric baseline.
    pub fn geometric_round_trips_per_sweep(&self) -> f64 {
        self.geometric_run.round_trips_per_sweep()
    }
}

/// Tune a β-ladder for the Fig 9a SK instance by round-trip-flux
/// feedback, then evaluate the tuned ladder head-to-head against a
/// geometric ladder at the same K over `eval_rounds` rounds (equal
/// sweep budget, same swap seed). The CSV (when named) writes one row
/// per rung: tuned β, geometric β, measured f(β) and acceptance of the
/// pair below each rung.
pub fn fig9a_sk_ladder_tuning<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    tuner: &TunerParams,
    eval_rounds: usize,
    csv_name: Option<&str>,
) -> Result<TunedLadderReport> {
    let topo = Topology::new();
    let problem = sk::chimera_pm_j(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;

    chip.randomize(seed ^ 0x71BE);
    let tuned = tune_ladder(chip, &problem, tuner, scale)?;

    let eval = |ladder: BetaLadder| TemperingParams {
        ladder,
        rounds: eval_rounds,
        adapt_every: 0,
        tuning: LadderTuning::Off,
        ..tuner.base.clone()
    };
    chip.randomize(seed ^ 0x7E39);
    let tuned_run = temper(chip, &problem, &eval(tuned.ladder.clone()), scale)?;
    let k = tuned.ladder.len();
    let geometric = BetaLadder::geometric(tuned.ladder.hottest(), tuned.ladder.coldest(), k);
    chip.randomize(seed ^ 0x7E39);
    let geometric_run = temper(chip, &problem, &eval(geometric), scale)?;
    // tempering leaves per-chain βs pinned; restore a uniform knob
    chip.set_beta(1.0);

    let report = TunedLadderReport { tuned, tuned_run, geometric_run };
    if let Some(name) = csv_name {
        let f = report.tuned_run.flux.f_profile();
        let acc = report.tuned_run.swaps.acceptance_rates();
        let rows: Vec<Vec<f64>> = (0..k)
            .map(|r| {
                vec![
                    r as f64,
                    report.tuned_run.ladder.betas[r],
                    report.geometric_run.ladder.betas[r],
                    f[r],
                    if r > 0 { acc[r - 1] } else { f64::NAN },
                ]
            })
            .collect();
        write_csv(name, "rung,tuned_beta,geometric_beta,fraction_up,pair_acceptance", &rows)?;
    }
    Ok(report)
}

/// The Fig 9a extension for the die array: one ladder sharded across
/// `params.shards` dies vs the same ladder on a single die.
#[derive(Debug, Clone)]
pub struct ShardedSkReport {
    /// The cross-die run (merged trace / swap stats, per-shard and
    /// boundary attribution).
    pub sharded: ShardedRun,
    /// The single-die reference run of `params.base` on die 0.
    pub single: TemperingRun,
    /// −n_edges, the ±J lower bound both arms are scored against.
    pub energy_lower_bound: f64,
}

/// Run the Fig 9a SK instance with one β-ladder sharded across
/// `params.shards` software dies (distinct mismatch personalities, as
/// in the coordinator's array) and, for reference, the same ladder on
/// a single die. Per-die chain counts are `die_batch` or the shard's
/// rung count, whichever is larger; spare chains scout at the hottest
/// β exactly as in [`crate::annealing::temper`].
pub fn fig9a_sk_temper_sharded(
    seed: u64,
    params: &ShardedTemperingParams,
    mcfg: MismatchConfig,
    die_batch: usize,
    csv_name: Option<&str>,
) -> Result<ShardedSkReport> {
    let topo = Topology::new();
    let problem = sk::chimera_pm_j(&topo, seed);
    let rungs = params.base.ladder.len();

    // single-die reference (die personality 0, all rungs on one die)
    let mut single_chip = super::software_chip(0xD1E5, mcfg, die_batch.max(rungs));
    let scale = super::program_problem(&mut single_chip, &topo, &problem)?;
    single_chip.randomize(seed ^ 0x7E39);
    let single = temper(&mut single_chip, &problem, &params.base, scale)?;

    // the sharded arm: one die personality per shard
    let (samplers, scale) =
        super::sharded_die_array(params, &problem, mcfg, die_batch, 0xD1E5, |s| {
            seed ^ 0xB04D ^ ((s as u64) << 8)
        })?;
    let sharded = run_sharded_tempering(samplers, &problem, params, scale)?;

    if let Some(name) = csv_name {
        write_csv_text(
            &format!("{name}_single"),
            "sweep,beta,mean_energy,min_energy",
            &single.trace.csv_rows(),
        )?;
        write_csv_text(
            &format!("{name}_sharded"),
            "sweep,beta,mean_energy,min_energy",
            &sharded.run.trace.csv_rows(),
        )?;
    }
    Ok(ShardedSkReport {
        sharded,
        single,
        energy_lower_bound: -(topo.edges.len() as f64),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::software_chip;

    #[test]
    fn sk_anneal_reaches_low_energy() {
        let mut chip = software_chip(2, MismatchConfig::default(), 8);
        let params = AnnealParams {
            schedule: BetaSchedule::Geometric { b0: 0.1, b1: 4.0 },
            steps: 32,
            sweeps_per_step: 4,
            record_every: 4,
        };
        let r = fig9a_sk_anneal(&mut chip, 5, &params, None).unwrap();
        // a short anneal on a ±J Chimera glass should already reach
        // below 55% of the (loose) lower bound on a mismatched die;
        // the fig9a bench runs the full-budget version
        assert!(
            r.best_energy < 0.55 * r.energy_lower_bound.abs() * -1.0,
            "best {} vs bound {}",
            r.best_energy,
            r.energy_lower_bound
        );
        // energy must decrease along the anneal
        let first = r.trace.rows.first().unwrap().2;
        let last = r.trace.rows.last().unwrap().2;
        assert!(last < first);
    }

    #[test]
    fn maxcut_native_beats_half_weight() {
        let topo = Topology::new();
        let g = Graph::chimera_native(&topo, 0.6, 3);
        let p = g.to_ising_native(&topo).unwrap();
        let mut chip = software_chip(4, MismatchConfig::default(), 8);
        let params = AnnealParams {
            schedule: BetaSchedule::Geometric { b0: 0.2, b1: 3.0 },
            steps: 24,
            sweeps_per_step: 4,
            record_every: 1,
        };
        let r = fig9b_maxcut(&mut chip, &g, &p, &params, None, None).unwrap();
        // random cut expects W/2; the chip must clearly beat it
        assert!(
            r.chip_best_cut > 0.6 * r.total_weight,
            "cut {} of W={}",
            r.chip_best_cut,
            r.total_weight
        );
        // trace is monotone
        for w in 1..r.chip_cut_trace.len() {
            assert!(r.chip_cut_trace[w].1 >= r.chip_cut_trace[w - 1].1);
        }
    }

    #[test]
    fn temper_vs_anneal_report_is_consistent() {
        let mut chip = software_chip(3, MismatchConfig::default(), 8);
        let anneal_params = AnnealParams {
            schedule: BetaSchedule::Geometric { b0: 0.1, b1: 4.0 },
            steps: 24,
            sweeps_per_step: 4,
            record_every: 1,
        };
        let temper_params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 4,
            rounds: 24,
            record_every: 1,
            ..Default::default()
        };
        let r =
            fig9a_sk_temper_vs_anneal(&mut chip, 7, &anneal_params, &temper_params, None).unwrap();
        assert_eq!(r.target_energy, r.anneal.best_energy);
        assert!(r.temper.best_energy.is_finite() && r.temper.best_energy < 0.0);
        // the anneal reaches its own best by construction
        let a = r.anneal_sweeps_to_target.expect("anneal reaches its own best");
        assert!(a <= 24 * 4);
        if let Some(t) = r.temper_sweeps_to_target {
            assert!(t <= r.temper.total_sweeps);
        }
        // swap diagnostics were collected
        assert!(r.temper.swaps.attempts.iter().sum::<u64>() > 0);
    }

    #[test]
    fn ladder_tuning_report_is_consistent() {
        let mut chip = software_chip(3, MismatchConfig::default(), 10);
        let tuner = TunerParams {
            base: TemperingParams {
                ladder: BetaLadder::geometric(0.15, 3.0, 8),
                sweeps_per_round: 2,
                rounds: 32,
                record_every: 8,
                ..Default::default()
            },
            max_iters: 4,
            tol: 0.1,
            ..Default::default()
        };
        let r = fig9a_sk_ladder_tuning(&mut chip, 5, &tuner, 48, None).unwrap();
        // both arms ran at the same K over the same span and budget
        assert_eq!(r.tuned_run.ladder.len(), r.geometric_run.ladder.len());
        assert_eq!(r.tuned_run.total_sweeps, r.geometric_run.total_sweeps);
        assert!((r.tuned_run.ladder.hottest() - 0.15).abs() < 1e-9);
        assert!((r.tuned_run.ladder.coldest() - 3.0).abs() < 1e-9);
        assert!(r.tuned_round_trips_per_sweep().is_finite());
        assert!(r.geometric_round_trips_per_sweep().is_finite());
        assert_eq!(r.tuned.f_profile.len(), r.tuned.ladder.len());
    }

    #[test]
    fn sharded_sk_report_is_consistent() {
        let params = ShardedTemperingParams {
            base: TemperingParams {
                ladder: BetaLadder::geometric(0.2, 3.0, 4),
                sweeps_per_round: 2,
                rounds: 16,
                record_every: 2,
                ..Default::default()
            },
            shards: 2,
            barrier_timeout: std::time::Duration::from_secs(30),
            pipeline: false,
            elastic: false,
        };
        let r = fig9a_sk_temper_sharded(3, &params, MismatchConfig::default(), 4, None).unwrap();
        assert!(r.sharded.run.best_energy.is_finite() && r.sharded.run.best_energy < 0.0);
        assert!(r.single.best_energy.is_finite());
        assert_eq!(r.sharded.shards, 2);
        // 4 rungs over 2 shards → one boundary after rung 1
        assert_eq!(r.sharded.boundary_pairs, vec![1]);
        // merging the attribution reproduces the global counters
        let mut merged = r.sharded.boundary.clone();
        for s in &r.sharded.per_shard {
            merged.merge(s);
        }
        assert_eq!(merged.attempts, r.sharded.run.swaps.attempts);
        assert_eq!(merged.accepts, r.sharded.run.swaps.accepts);
        assert_eq!(merged.round_trips, r.sharded.run.swaps.round_trips);
    }

    #[test]
    fn sweeps_to_reach_uses_running_min() {
        let mut t = EnergyTrace::default();
        t.push(4, 0.5, -1.0, -5.0);
        t.push(8, 0.7, -2.0, -3.0); // later row is worse; running min holds
        assert_eq!(sweeps_to_reach(&t, -5.0), Some(4));
        assert_eq!(sweeps_to_reach(&t, -4.9), Some(4));
        assert_eq!(sweeps_to_reach(&t, -6.0), None);
    }

    #[test]
    fn maxcut_embedded_k8_near_exact() {
        let topo = Topology::new();
        let g = Graph::random(8, 0.8, 11);
        let emb = crate::chimera::Embedding::clique(&topo, 2, 1.5).unwrap();
        let p = g.to_ising_embedded(&topo, &emb).unwrap();
        let mut chip = software_chip(6, MismatchConfig::default(), 8);
        let params = AnnealParams {
            schedule: BetaSchedule::Geometric { b0: 0.2, b1: 4.0 },
            steps: 32,
            sweeps_per_step: 4,
            record_every: 1,
        };
        let r = fig9b_maxcut(&mut chip, &g, &p, &params, Some(&emb), None).unwrap();
        let exact = r.exact_cut.unwrap();
        assert!(
            r.chip_best_cut >= 0.85 * exact,
            "embedded cut {} vs exact {exact}",
            r.chip_best_cut
        );
    }
}
