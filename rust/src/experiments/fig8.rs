//! Fig 8a — per-p-bit tanh transfer curves and chip variability measured
//! exactly the way the authors did: sweep the bias DAC code, average the
//! spin, fit the resulting tanh.
//!
//! Fig 8b — the full-adder distribution during learning (same machinery
//! as Fig 7 on the 5-visible adder layout).

use anyhow::Result;

use crate::chimera::full_adder_layout;
use crate::config::MismatchConfig;
use crate::learning::dataset;
use crate::learning::TrainableChip;
use crate::util::bench::write_csv;

use super::fig7::{fig7_gate_learning, GateExperiment, GateReport};

/// Fig 8a output.
#[derive(Debug, Clone)]
pub struct BiasSweepReport {
    /// Bias codes swept.
    pub codes: Vec<i8>,
    /// `[pbit][code]` measured ⟨m⟩.
    pub mean_spin: Vec<Vec<f64>>,
    /// Per-p-bit fitted slope (β·g_i, from the steepest-point secant).
    pub slopes: Vec<f64>,
    /// Per-p-bit fitted offset (code where ⟨m⟩ crosses 0).
    pub offsets: Vec<f64>,
    /// Relative slope spread (σ/μ) — the paper's variability number.
    pub slope_cv: f64,
    /// Offset spread in DAC codes.
    pub offset_sd_codes: f64,
}

/// Sweep the bias DAC of `pbits` and measure ⟨m⟩ (Fig 8a).
pub fn fig8a_bias_sweep<C: TrainableChip>(
    chip: &mut C,
    pbits: &[usize],
    codes: &[i8],
    samples_per_point: usize,
    beta: f64,
    csv_name: Option<&str>,
) -> Result<BiasSweepReport> {
    let topo = crate::chimera::Topology::new();
    let ne = topo.edges.len();
    chip.set_beta(beta as f32);
    let mut mean_spin = vec![vec![0.0f64; codes.len()]; pbits.len()];
    for (ci, &code) in codes.iter().enumerate() {
        // program the swept bias on all observed p-bits at once — they
        // are chosen non-interacting (no couplers enabled).
        let mut w = crate::analog::ProgrammedWeights::zeros(ne);
        for &p in pbits {
            w.h_codes[p] = code;
        }
        chip.program_codes(&w)?;
        chip.sweeps(8)?; // thermalize
        let mut acc = vec![0.0f64; pbits.len()];
        let mut n = 0usize;
        while n * chip.batch() < samples_per_point {
            chip.sweeps(1)?;
            for st in chip.states() {
                for (k, &p) in pbits.iter().enumerate() {
                    acc[k] += st[p] as f64;
                }
            }
            n += 1;
        }
        for (k, a) in acc.iter().enumerate() {
            mean_spin[k][ci] = a / (n * chip.batch()) as f64;
        }
    }
    // fit slope & offset per p-bit
    let mut slopes = Vec::with_capacity(pbits.len());
    let mut offsets = Vec::with_capacity(pbits.len());
    for curve in &mean_spin {
        let (slope, offset) = fit_tanh(codes, curve);
        slopes.push(slope);
        offsets.push(offset);
    }
    let mu = slopes.iter().sum::<f64>() / slopes.len() as f64;
    let sd =
        (slopes.iter().map(|s| (s - mu).powi(2)).sum::<f64>() / slopes.len() as f64).sqrt();
    let omu = offsets.iter().sum::<f64>() / offsets.len() as f64;
    let osd =
        (offsets.iter().map(|o| (o - omu).powi(2)).sum::<f64>() / offsets.len() as f64).sqrt();
    if let Some(name) = csv_name {
        let mut rows = Vec::new();
        for (ci, &code) in codes.iter().enumerate() {
            let mut row = vec![code as f64];
            for curve in &mean_spin {
                row.push(curve[ci]);
            }
            rows.push(row);
        }
        let header = std::iter::once("code".to_string())
            .chain(pbits.iter().map(|p| format!("pbit{p}")))
            .collect::<Vec<_>>()
            .join(",");
        write_csv(name, &header, &rows)?;
    }
    Ok(BiasSweepReport {
        codes: codes.to_vec(),
        mean_spin,
        slopes,
        offsets,
        slope_cv: sd / mu.abs().max(1e-12),
        offset_sd_codes: osd,
    })
}

/// tanh fit by linearization: atanh(⟨m⟩) = slope·(code/127) + b, solved
/// by least squares over the unsaturated points (|⟨m⟩| < 0.95, which
/// de-weights the noisy tails); offset is the zero-crossing in codes.
fn fit_tanh(codes: &[i8], curve: &[f64]) -> (f64, f64) {
    let (mut sx, mut sy, mut sxx, mut sxy, mut n) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for (i, &c) in codes.iter().enumerate() {
        let y = curve[i];
        if y.abs() >= 0.95 {
            continue;
        }
        let x = c as f64 / 127.0;
        let z = y.atanh();
        sx += x;
        sy += z;
        sxx += x * x;
        sxy += x * z;
        n += 1.0;
    }
    if n < 3.0 {
        // fully saturated curve (very steep tanh): report a floor fit
        return (f64::INFINITY, 0.0);
    }
    let denom = (n * sxx - sx * sx).max(1e-12);
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    let offset_codes = -intercept / slope.max(1e-12) * 127.0;
    (slope, offset_codes)
}

/// Fig 8b: full-adder learning = the Fig 7 machinery on the adder layout.
pub fn fig8b_adder_learning<C: TrainableChip>(
    params: crate::learning::CdParams,
    mismatch: MismatchConfig,
    chip: &mut C,
    snapshot_epochs: Vec<usize>,
    eval_samples: usize,
    csv_name: Option<&str>,
) -> Result<GateReport> {
    let exp = GateExperiment {
        layout: full_adder_layout(0, 1),
        dataset: dataset::full_adder(),
        params,
        mismatch,
        chip_seed: 0,
        snapshot_epochs,
        eval_samples,
    };
    fig7_gate_learning(&exp, chip, csv_name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::{ideal_chip, software_chip};

    #[test]
    fn ideal_chip_sweep_matches_theory() {
        let mut chip = ideal_chip(1, 8);
        let codes: Vec<i8> = (-120..=120).step_by(24).map(|c| c as i8).collect();
        let r = fig8a_bias_sweep(&mut chip, &[0, 100], &codes, 1200, 1.0, None).unwrap();
        // ⟨m⟩ = tanh(β h) with h = code/127
        for curve in &r.mean_spin {
            for (ci, &code) in codes.iter().enumerate() {
                let want = ((code as f64 / 127.0) as f64).tanh();
                assert!(
                    (curve[ci] - want).abs() < 0.08,
                    "code {code}: {} vs {want}",
                    curve[ci]
                );
            }
        }
        // ideal chip: slopes essentially identical
        assert!(r.slope_cv < 0.08, "ideal slope CV {}", r.slope_cv);
    }

    #[test]
    fn mismatched_chip_shows_spread() {
        let cfg = MismatchConfig { sigma_beta: 0.2, sigma_obeta: 0.1, ..Default::default() };
        let mut chip = software_chip(3, cfg, 8);
        let codes: Vec<i8> = (-120..=120).step_by(30).map(|c| c as i8).collect();
        let pbits: Vec<usize> = (0..16).map(|k| k * 16).collect();
        let r = fig8a_bias_sweep(&mut chip, &pbits, &codes, 600, 1.0, None).unwrap();
        let mut ideal = ideal_chip(4, 8);
        let ri = fig8a_bias_sweep(&mut ideal, &pbits, &codes, 600, 1.0, None).unwrap();
        assert!(
            r.slope_cv > 2.0 * ri.slope_cv,
            "mismatched CV {} vs ideal {}",
            r.slope_cv,
            ri.slope_cv
        );
    }
}
