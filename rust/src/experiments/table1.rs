//! Table 1: the comparison row for "This Work" — sampling throughput,
//! spin-flips/s and TTS(99 %) on a planted-solution glass, plus the chip
//! spec constants the table quotes.

use anyhow::Result;

use crate::annealing::{
    anneal, temper, tts99, tts99_counts, tune_ladder, AnnealParams, BetaLadder, BetaSchedule,
    LadderTuning, TemperingParams, TtsEstimate, TunerParams,
};
use crate::chimera::Topology;
use crate::chip::SAMPLE_TIME_NS;
use crate::config::MismatchConfig;
use crate::coordinator::{run_sharded_tempering, ShardedTemperingParams};
use crate::learning::TrainableChip;
use crate::metrics::SwapStats;
use crate::problems::{sk, IsingProblem};
use crate::sampler::Sampler;
use crate::util::bench::write_csv;

/// Table 1 measurement for one engine.
#[derive(Debug, Clone)]
pub struct Table1Report {
    /// p(reach planted ground state) per anneal restart.
    pub p_success: f64,
    /// The derived TTS(99 %) estimate.
    pub tts: TtsEstimate,
    /// Simulated chip time per restart (ns) — 50 ns × sweeps.
    pub chip_time_per_restart_ns: f64,
    /// Host wall-clock spin-flips per second of the engine.
    pub host_flips_per_sec: f64,
    /// Chip-referred flips per second (440 spins / 50 ns).
    pub chip_flips_per_sec: f64,
    /// Restarts measured.
    pub restarts: usize,
    /// Per-replica sweeps per restart.
    pub sweeps_per_restart: usize,
}

/// Measure TTS on a planted ±J glass: anneal `restarts` times, count how
/// often the planted ground energy is reached.
pub fn table1_tts<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    restarts: usize,
    params: &AnnealParams,
    csv_name: Option<&str>,
) -> Result<Table1Report> {
    let topo = Topology::new();
    let (problem, _hidden, e0) = sk::planted(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;

    let sweeps_per_restart = params.steps * params.sweeps_per_step;
    let mut successes = 0usize;
    let mut attempts = 0usize;
    let t_host = std::time::Instant::now();
    let mut total_sweep_batches = 0u64;
    for r in 0..restarts {
        chip.randomize(seed ^ (0x7755 + r as u64));
        let (_, best) = anneal(chip, &problem, params, scale)?;
        for (e, _) in best {
            attempts += 1;
            // quantization to ±127 keeps J = ±1 exact, so the planted
            // energy is representable exactly; allow a whisker.
            if e <= e0 + 1e-6 {
                successes += 1;
            }
        }
        total_sweep_batches += sweeps_per_restart as u64;
    }
    let host_elapsed = t_host.elapsed().as_secs_f64();
    let host_flips =
        total_sweep_batches as f64 * chip.batch() as f64 * crate::N_SPINS as f64;

    let p = successes as f64 / attempts.max(1) as f64;
    let chip_time = sweeps_per_restart as f64 * SAMPLE_TIME_NS;
    let report = Table1Report {
        p_success: p,
        tts: tts99(p, chip_time, restarts),
        chip_time_per_restart_ns: chip_time,
        host_flips_per_sec: host_flips / host_elapsed,
        chip_flips_per_sec: crate::N_SPINS as f64 / (SAMPLE_TIME_NS * 1e-9),
        restarts,
        sweeps_per_restart,
    };
    if let Some(name) = csv_name {
        write_csv(
            name,
            "p_success,tts99_ns,chip_time_per_restart_ns,host_flips_per_sec,chip_flips_per_sec",
            &[vec![
                report.p_success,
                report.tts.tts99_ns,
                report.chip_time_per_restart_ns,
                report.host_flips_per_sec,
                report.chip_flips_per_sec,
            ]],
        )?;
    }
    Ok(report)
}

/// Measure TTS on the same planted ±J glass with replica exchange: run
/// `repeats` independent tempering runs, count how many reach the
/// planted ground energy. One "restart" is a whole K-replica run (its
/// replicas occupy the die concurrently, so chip time stays sweeps ×
/// 50 ns) — numbers are directly comparable with [`table1_tts`] when
/// the per-replica sweep budgets match.
pub fn table1_tts_tempering<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    repeats: usize,
    params: &TemperingParams,
    csv_name: Option<&str>,
) -> Result<Table1Report> {
    let topo = Topology::new();
    let (problem, _hidden, e0) = sk::planted(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;
    let (report, _rt_per_sweep) =
        measure_tts_tempering(chip, &problem, e0, scale, seed, repeats, params)?;
    chip.set_beta(1.0);
    if let Some(name) = csv_name {
        write_csv(
            name,
            "p_success,tts99_ns,chip_time_per_restart_ns,host_flips_per_sec,chip_flips_per_sec",
            &[vec![
                report.p_success,
                report.tts.tts99_ns,
                report.chip_time_per_restart_ns,
                report.host_flips_per_sec,
                report.chip_flips_per_sec,
            ]],
        )?;
    }
    Ok(report)
}

/// [`table1_tts_tempering`] with the ladder sharded across a die array.
#[derive(Debug, Clone)]
pub struct ShardedTtsReport {
    /// The TTS measurement itself.
    pub report: Table1Report,
    /// Swap counters merged over every repeat (global: interior and
    /// boundary pairs alike).
    pub merged_swaps: SwapStats,
    /// Boundary-pair counters merged over every repeat.
    pub boundary: SwapStats,
    /// Pair indices of the shard boundaries.
    pub boundary_pairs: Vec<usize>,
    /// Hot→cold→hot excursions that crossed dies, summed over repeats.
    pub cross_shard_round_trips: u64,
}

/// Measure TTS on the planted ±J glass with **one ladder sharded
/// across `params.shards` dies** — the cross-die analog of
/// [`table1_tts_tempering`]. Each repeat rebuilds the same die array
/// (fixed per-shard personalities) and counts a success when the run's
/// best energy reaches the planted ground energy. Chip time per repeat
/// stays sweeps × 50 ns: the shards run concurrently, which is the
/// entire point of the array.
pub fn table1_tts_sharded(
    seed: u64,
    repeats: usize,
    params: &ShardedTemperingParams,
    mcfg: MismatchConfig,
    die_batch: usize,
    csv_name: Option<&str>,
) -> Result<ShardedTtsReport> {
    let topo = Topology::new();
    let (problem, _hidden, e0) = sk::planted(&topo, seed);
    let rungs = params.base.ladder.len();
    anyhow::ensure!(
        params.shards >= 1 && params.shards <= rungs,
        "need between 1 and {rungs} shards, got {}",
        params.shards
    );

    let mut successes = 0usize;
    let mut merged_swaps = SwapStats::new(rungs);
    let mut boundary = SwapStats::new(rungs);
    let mut boundary_pairs = Vec::new();
    let mut cross_trips = 0u64;
    let mut total_chains = 0usize;
    let t_host = std::time::Instant::now();
    for r in 0..repeats {
        // rebuild the same die array each repeat (fixed personalities),
        // re-randomizing the starting states per repeat and shard
        let (samplers, scale) =
            super::sharded_die_array(params, &problem, mcfg, die_batch, 0x7A81, |s| {
                seed ^ (0x7E44 + r as u64) ^ ((s as u64) << 16)
            })?;
        total_chains = samplers.iter().map(|c| c.batch()).sum();
        let mut p = params.clone();
        p.base.seed = params.base.seed.wrapping_add(r as u64);
        let run = run_sharded_tempering(samplers, &problem, &p, scale)?;
        if run.run.best_energy <= e0 + 1e-6 {
            successes += 1;
        }
        merged_swaps.merge(&run.run.swaps);
        boundary.merge(&run.boundary);
        cross_trips += run.cross_shard_round_trips();
        boundary_pairs = run.boundary_pairs;
    }
    let host_elapsed = t_host.elapsed().as_secs_f64();
    let total_sweeps = (repeats * params.base.total_sweeps()) as f64;
    let host_flips = total_sweeps * total_chains as f64 * crate::N_SPINS as f64;

    let tts = tts99_counts(successes, repeats, params.base.chip_time_ns());
    let report = Table1Report {
        p_success: tts.p_success,
        tts,
        chip_time_per_restart_ns: params.base.chip_time_ns(),
        host_flips_per_sec: host_flips / host_elapsed,
        chip_flips_per_sec: crate::N_SPINS as f64 / (SAMPLE_TIME_NS * 1e-9),
        restarts: repeats,
        sweeps_per_restart: params.base.total_sweeps(),
    };
    if let Some(name) = csv_name {
        write_csv(
            name,
            "p_success,tts99_ns,chip_time_per_restart_ns,cross_shard_round_trips",
            &[vec![
                report.p_success,
                report.tts.tts99_ns,
                report.chip_time_per_restart_ns,
                cross_trips as f64,
            ]],
        )?;
    }
    Ok(ShardedTtsReport {
        report,
        merged_swaps,
        boundary,
        boundary_pairs,
        cross_shard_round_trips: cross_trips,
    })
}

/// The shared TTS measurement loop over `repeats` tempering runs of an
/// already-programmed planted instance: per-repeat re-randomize and
/// swap-seed step, success counting against the planted energy `e0`,
/// host-flips accounting, and round trips per replica-sweep (the datum
/// the tuned-ladder arm compares across ladders). Leaves per-chain βs
/// pinned; callers restore the uniform knob.
fn measure_tts_tempering<C: TrainableChip>(
    chip: &mut C,
    problem: &IsingProblem,
    e0: f64,
    scale: f64,
    seed: u64,
    repeats: usize,
    params: &TemperingParams,
) -> Result<(Table1Report, f64)> {
    let mut successes = 0usize;
    let mut round_trips = 0u64;
    let mut sweeps = 0u64;
    let t_host = std::time::Instant::now();
    for r in 0..repeats {
        chip.randomize(seed ^ (0x7E44 + r as u64));
        let mut p = params.clone();
        p.seed = params.seed.wrapping_add(r as u64);
        let run = temper(chip, problem, &p, scale)?;
        if run.best_energy <= e0 + 1e-6 {
            successes += 1;
        }
        round_trips += run.swaps.round_trips;
        sweeps += run.total_sweeps;
    }
    let host_elapsed = t_host.elapsed().as_secs_f64();
    let host_flips = sweeps as f64 * chip.batch() as f64 * crate::N_SPINS as f64;
    let tts = tts99_counts(successes, repeats, params.chip_time_ns());
    let report = Table1Report {
        p_success: tts.p_success,
        tts,
        chip_time_per_restart_ns: params.chip_time_ns(),
        host_flips_per_sec: host_flips / host_elapsed,
        chip_flips_per_sec: crate::N_SPINS as f64 / (SAMPLE_TIME_NS * 1e-9),
        restarts: repeats,
        sweeps_per_restart: params.total_sweeps(),
    };
    let rt_per_sweep = if sweeps == 0 { 0.0 } else { round_trips as f64 / sweeps as f64 };
    Ok((report, rt_per_sweep))
}

/// The tuned-ladder arm of the Table 1 tempering comparison.
#[derive(Debug, Clone)]
pub struct TunedTtsReport {
    /// TTS measured with the flux-tuned ladder.
    pub tuned: Table1Report,
    /// TTS measured with a geometric ladder at the same K and span.
    pub geometric: Table1Report,
    /// The tuned ladder itself.
    pub ladder: BetaLadder,
    /// Whether the tuner converged within its budget.
    pub converged: bool,
    /// Round trips per replica-sweep over the tuned-arm repeats.
    pub tuned_round_trips_per_sweep: f64,
    /// Round trips per replica-sweep over the geometric-arm repeats.
    pub geometric_round_trips_per_sweep: f64,
}

/// [`table1_tts_tempering`] with a flux-tuned ladder: tune once on the
/// planted instance ([`crate::annealing::tune_ladder`]), then measure
/// TTS with the tuned ladder *and* with a geometric ladder at the same
/// K — the round-trips-per-sweep columns say what the tuning bought
/// (tuning sweeps are reported by the tuner, not charged to TTS, since
/// a tuned ladder is reused across every subsequent job).
pub fn table1_tts_tuned<C: TrainableChip>(
    chip: &mut C,
    seed: u64,
    repeats: usize,
    tuner: &TunerParams,
    csv_name: Option<&str>,
) -> Result<TunedTtsReport> {
    let topo = Topology::new();
    let (problem, _hidden, e0) = sk::planted(&topo, seed);
    let scale = super::program_problem(chip, &topo, &problem)?;

    chip.randomize(seed ^ 0x71BE);
    let tuned = tune_ladder(chip, &problem, tuner, scale)?;
    let k = tuned.ladder.len();
    let geometric = BetaLadder::geometric(tuned.ladder.hottest(), tuned.ladder.coldest(), k);

    let arm_params = |ladder: &BetaLadder| TemperingParams {
        ladder: ladder.clone(),
        adapt_every: 0,
        tuning: LadderTuning::Off,
        ..tuner.base.clone()
    };
    let (tuned_report, tuned_rt) = measure_tts_tempering(
        chip,
        &problem,
        e0,
        scale,
        seed,
        repeats,
        &arm_params(&tuned.ladder),
    )?;
    let (geo_report, geo_rt) = measure_tts_tempering(
        chip,
        &problem,
        e0,
        scale,
        seed,
        repeats,
        &arm_params(&geometric),
    )?;
    chip.set_beta(1.0);

    if let Some(name) = csv_name {
        write_csv(
            name,
            "arm,p_success,tts99_ns,round_trips_per_sweep",
            &[
                vec![0.0, tuned_report.p_success, tuned_report.tts.tts99_ns, tuned_rt],
                vec![1.0, geo_report.p_success, geo_report.tts.tts99_ns, geo_rt],
            ],
        )?;
    }
    Ok(TunedTtsReport {
        tuned: tuned_report,
        geometric: geo_report,
        ladder: tuned.ladder,
        converged: tuned.converged,
        tuned_round_trips_per_sweep: tuned_rt,
        geometric_round_trips_per_sweep: geo_rt,
    })
}

/// Default tempering setup matching [`default_tts_params`]'s per-replica
/// budget (48 × 4 = 192 sweeps) and β span.
pub fn default_tts_temper_params() -> TemperingParams {
    TemperingParams {
        ladder: BetaLadder::geometric(0.15, 5.0, 8),
        sweeps_per_round: 4,
        rounds: 48,
        record_every: 8,
        seed: 0x7715,
        ..Default::default()
    }
}

/// Default tuner setup for the Table 1 planted glass: feedback over
/// [`default_tts_temper_params`]'s β span and per-burst budget.
pub fn default_tts_tuner_params() -> TunerParams {
    TunerParams { base: default_tts_temper_params(), ..Default::default() }
}

/// The static spec constants Table 1 quotes for "This Work".
pub fn spec_row() -> Vec<(&'static str, String)> {
    vec![
        ("Technology", "65nm (Mixed-Signal), simulated".into()),
        ("Spin memory", "Flip-Flop".into()),
        ("Spin State update", "Digital (Binary State)".into()),
        ("Graph Topology", "Chimera (8x spins)".into()),
        ("Ising Hamiltonian", "Gibbs Sampling".into()),
        ("Supply", "1V".into()),
        ("Spins#", crate::N_SPINS.to_string()),
        ("Core size", "0.44mm2".into()),
        ("TTS", format!("{} ns/sample", SAMPLE_TIME_NS)),
    ]
}

/// Default Table 1 anneal (fast ramp — the chip's 50 ns samples make
/// short anneals cheap; TTS trades p_success against restart length).
pub fn default_tts_params() -> AnnealParams {
    AnnealParams {
        schedule: BetaSchedule::Geometric { b0: 0.15, b1: 5.0 },
        steps: 48,
        sweeps_per_step: 4,
        record_every: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MismatchConfig;
    use crate::experiments::software_chip;

    #[test]
    fn planted_glass_is_solvable_and_tts_finite() {
        let mut chip = software_chip(8, MismatchConfig::ideal(), 8);
        let params = default_tts_params();
        let r = table1_tts(&mut chip, 3, 4, &params, None).unwrap();
        assert!(r.p_success > 0.0, "no restart found the planted state");
        assert!(r.tts.tts99_ns.is_finite());
        assert!(r.chip_flips_per_sec > 8e9); // 440 / 50ns = 8.8e9
        assert_eq!(r.sweeps_per_restart, 48 * 4);
    }

    #[test]
    fn sharded_tts_on_planted_glass() {
        let params = ShardedTemperingParams {
            base: default_tts_temper_params(),
            shards: 2,
            barrier_timeout: std::time::Duration::from_secs(30),
            pipeline: false,
            elastic: false,
        };
        let r = table1_tts_sharded(3, 4, &params, MismatchConfig::ideal(), 4, None).unwrap();
        assert!(r.report.p_success > 0.0, "no sharded run found the planted state");
        assert_eq!(r.report.sweeps_per_restart, 48 * 4);
        // shards run concurrently: chip time must not scale with K or shards
        assert_eq!(r.report.chip_time_per_restart_ns, 192.0 * SAMPLE_TIME_NS);
        // 8 rungs over 2 shards → one boundary pair, which saw traffic
        assert_eq!(r.boundary_pairs, vec![3]);
        assert!(r.boundary.attempts[3] > 0, "boundary pair never attempted");
    }

    #[test]
    fn tempering_tts_on_planted_glass() {
        let mut chip = software_chip(9, MismatchConfig::ideal(), 8);
        let params = default_tts_temper_params();
        let r = table1_tts_tempering(&mut chip, 3, 6, &params, None).unwrap();
        assert!(r.p_success > 0.0, "no tempering run found the planted state");
        assert!(r.tts.tts99_ns.is_finite());
        assert_eq!(r.sweeps_per_restart, 48 * 4);
        // K replicas run concurrently: restart time must not scale with K
        assert_eq!(r.chip_time_per_restart_ns, 192.0 * SAMPLE_TIME_NS);
    }

    #[test]
    fn tuned_tts_on_planted_glass() {
        let mut chip = software_chip(9, MismatchConfig::ideal(), 8);
        let tuner = TunerParams {
            base: TemperingParams {
                rounds: 24,
                ..default_tts_temper_params()
            },
            max_iters: 3,
            tol: 0.1,
            ..Default::default()
        };
        let r = table1_tts_tuned(&mut chip, 3, 4, &tuner, None).unwrap();
        // both arms measured the same budget at the same K
        assert_eq!(r.tuned.sweeps_per_restart, r.geometric.sweeps_per_restart);
        assert_eq!(r.tuned.restarts, 4);
        assert!(r.ladder.betas.windows(2).all(|w| w[1] > w[0]));
        assert!(r.tuned_round_trips_per_sweep.is_finite());
        assert!(r.geometric_round_trips_per_sweep.is_finite());
        // chip time per repeat must not scale with K (replicas run
        // concurrently on-die), matching the untuned tempering arm
        assert_eq!(r.tuned.chip_time_per_restart_ns, 24.0 * 4.0 * SAMPLE_TIME_NS);
    }

    #[test]
    fn spec_row_quotes_the_paper() {
        let row = spec_row();
        assert!(row.iter().any(|(k, v)| *k == "Spins#" && v == "440"));
        assert!(row.iter().any(|(k, v)| *k == "Graph Topology" && v.contains("Chimera")));
    }
}
