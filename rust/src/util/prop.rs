//! Property-test driver (proptest is not in the offline vendor set).
//!
//! [`check`] runs an invariant over many seeded random cases and, on
//! failure, reports the seed so the case can be replayed exactly:
//!
//! ```ignore
//! prop::check("router pairs jobs", 500, |rng| {
//!     let n = rng.below(64) + 1;
//!     /* build a case from rng, assert the invariant */
//! });
//! ```
//!
//! No shrinking; cases should be built smallest-first where practical.

use crate::rng::HostRng;

/// Run `cases` random cases of `f`. Panics with the offending seed on the
/// first failure (assert! inside `f` as usual).
pub fn check<F: FnMut(&mut HostRng)>(name: &str, cases: u64, mut f: F) {
    // Fixed base so CI is deterministic; override with PCHIP_PROP_SEED.
    let base: u64 = std::env::var("PCHIP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = HostRng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            eprintln!(
                "property `{name}` failed at case {case} (replay with PCHIP_PROP_SEED={base} and case {case}, rng seed {seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 xor involution", 100, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            assert_eq!((x ^ k) ^ k, x);
        });
    }

    #[test]
    #[should_panic]
    fn surfaces_failures() {
        check("always fails eventually", 50, |rng| {
            assert!(rng.uniform() < 0.9, "hit the failing tail");
        });
    }
}
