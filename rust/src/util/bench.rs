//! Tiny criterion-style benchmark harness (criterion is not available in
//! the offline vendor set). Provides warmup, timed iterations, and
//! mean / p50 / p95 reporting, plus a CSV writer so every paper
//! figure/table bench can dump its series to `results/`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label the measurement is reported under.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Mean wall-clock time per iteration.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Optional derived throughput (unit/s), set via [`Bench::throughput`].
    pub throughput: Option<(f64, &'static str)>,
}

impl Measurement {
    /// Print the one-line bench report to stdout.
    pub fn report(&self) {
        let t = |d: Duration| {
            if d.as_secs_f64() >= 1.0 {
                format!("{:.3} s", d.as_secs_f64())
            } else if d.as_secs_f64() >= 1e-3 {
                format!("{:.3} ms", d.as_secs_f64() * 1e3)
            } else {
                format!("{:.3} µs", d.as_secs_f64() * 1e6)
            }
        };
        let tp = self
            .throughput
            .map(|(v, unit)| format!("  [{v:.3e} {unit}/s]"))
            .unwrap_or_default();
        println!(
            "bench {:<42} mean {:>11}  p50 {:>11}  p95 {:>11}  min {:>11}  ({} iters){tp}",
            self.name,
            t(self.mean),
            t(self.p50),
            t(self.p95),
            t(self.min),
            self.iters
        );
    }
}

/// Benchmark runner.
pub struct Bench {
    warmup: usize,
    iters: usize,
    elements: Option<(f64, &'static str)>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 20, elements: None }
    }
}

impl Bench {
    /// Runner with `warmup` untimed and `iters` timed iterations.
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters, elements: None }
    }

    /// Declare that each iteration processes `n` of `unit`, enabling
    /// throughput reporting (e.g. `.throughput(1e6, "flips")`).
    pub fn throughput(mut self, n: f64, unit: &'static str) -> Self {
        self.elements = Some((n, unit));
        self
    }

    /// Run `f` and report. Returns the measurement for CSV logging.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let mean = total / self.iters as u32;
        let m = Measurement {
            name: name.to_string(),
            iters: self.iters,
            mean,
            p50: times[self.iters / 2],
            p95: times[(self.iters * 95 / 100).min(self.iters - 1)],
            min: times[0],
            throughput: self.elements.map(|(n, u)| (n / mean.as_secs_f64(), u)),
        };
        m.report();
        m
    }
}

/// Write rows to `results/<name>.csv` (header + rows of f64 columns).
pub fn write_csv(
    name: &str,
    header: &str,
    rows: &[Vec<f64>],
) -> std::io::Result<std::path::PathBuf> {
    let text_rows: Vec<Vec<String>> =
        rows.iter().map(|row| row.iter().map(|x| format!("{x}")).collect()).collect();
    write_csv_text(name, header, &text_rows)
}

/// Write pre-formatted cells to `results/<name>.csv` — the exact-width
/// variant for columns (u64 sweep counters) that an f64 cell would
/// round above 2^53.
pub fn write_csv_text(
    name: &str,
    header: &str,
    rows: &[Vec<String>],
) -> std::io::Result<std::path::PathBuf> {
    let dir = crate::config::results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut text = String::from(header);
    text.push('\n');
    for row in rows {
        text.push_str(&row.join(","));
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    Ok(path)
}

/// Whether `PCHIP_BENCH_QUICK` asks for the reduced-budget bench arms —
/// the CI smoke leg sets it so every PR regenerates the `BENCH_*.json`
/// perf records in seconds; local runs keep the full budgets.
pub fn quick() -> bool {
    std::env::var_os("PCHIP_BENCH_QUICK").is_some()
}

/// Write a machine-readable bench report to
/// `<repo root>/BENCH_<name>.json` — the perf-trajectory records the CI
/// bench-smoke leg regenerates and uploads as workflow artifacts.
pub fn write_bench_json(
    name: &str,
    report: &crate::util::json::Json,
) -> std::io::Result<std::path::PathBuf> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join(format!("BENCH_{name}.json"));
    std::fs::write(&path, report.to_string())?;
    Ok(path)
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::new(1, 5);
        let m = b.run("spin-loop", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.mean > Duration::ZERO);
        assert!(m.p95 >= m.p50);
        assert!(m.min <= m.mean);
    }

    #[test]
    fn throughput_derived() {
        let b = Bench::new(0, 3).throughput(1000.0, "ops");
        let m = b.run("nop", || std::thread::sleep(Duration::from_micros(50)));
        let (tp, unit) = m.throughput.unwrap();
        assert_eq!(unit, "ops");
        assert!(tp > 0.0 && tp < 1e9);
    }

    #[test]
    fn csv_writes() {
        std::env::set_var("PCHIP_RESULTS", std::env::temp_dir().join("pchip_test_results"));
        let p = write_csv("unit_test", "a,b", &[vec![1.0, 2.0], vec![3.0, 4.5]]).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("3,4.5"));
        std::env::remove_var("PCHIP_RESULTS");
    }
}
