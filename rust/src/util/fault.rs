//! Deterministic fault injection for gang-recovery testing.
//!
//! A [`FaultPlan`] scripts die failures in *logical* time: each entry
//! names a die, the index of the `sweeps()` call at which the fault
//! fires, and what happens ([`FaultKind`]). Wrapping a die's engine in
//! [`FaultyChip`] then makes every recovery path — shrink, regrow,
//! stall-detection — reproducible in `cargo test` from a seed, with no
//! wall-clock races:
//!
//! ```ignore
//! let plan = FaultPlan::new(vec![FaultEvent {
//!     die: 1,
//!     round: 3,
//!     kind: FaultKind::Kill { until: Some(6) },
//! }]);
//! let chip = FaultyChip::new(inner, 1, plan); // die 1 dies on its 4th
//!                                             // sweeps() call, revives
//!                                             // on its 7th
//! ```
//!
//! Faults count a die's **own** `sweeps()` calls, not wall-clock time
//! or coordinator rounds. For sharded tempering the two coincide (one
//! `sweeps()` per phase command); for training, a killed die consumes
//! exactly one call per probe epoch (the first `sweeps()` of the epoch
//! shard fails), so revival timing is deterministic there too.
//!
//! Plans serialize to JSON ([`FaultPlan::to_json`] /
//! [`FaultPlan::from_json`]) so a failing chaos-suite case can be
//! uploaded as a CI artifact and replayed verbatim; [`FaultPlan::chaos`]
//! generates a small random plan from a seed for the chaos matrix.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::analog::Folded;
use crate::problems::EnergyLedger;
use crate::rng::HostRng;
use crate::sampler::Sampler;
use crate::util::json::{obj, Json};

/// What happens to a die when one of its fault events fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Every `sweeps()` call in `[round, until)` fails with an error
    /// (`None` = the die never comes back). The worker reports the
    /// error immediately, so recovery is prompt and deterministic —
    /// this is the workhorse of the chaos suite.
    Kill {
        /// First call index at which the die works again; `None` kills
        /// it for good.
        until: Option<usize>,
    },
    /// The `sweeps()` call blocks for an hour — the die goes silent
    /// without an error, exercising the barrier-timeout path. The
    /// worker thread is abandoned by the coordinator and dies with the
    /// process (the same contract the old ad-hoc stalling samplers
    /// pinned down).
    Stall,
    /// The `sweeps()` call completes, but only after sleeping `ms`
    /// milliseconds — timing skew without failure, for pipelining
    /// tests.
    Delay {
        /// Added latency in milliseconds.
        ms: u64,
    },
}

/// One scripted fault: `die` suffers `kind` at its `round`-th
/// `sweeps()` call (0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Which die the fault targets.
    pub die: usize,
    /// The die-local `sweeps()`-call index at which it fires.
    pub round: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic schedule of die faults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// The scripted events, in no particular order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A plan from explicit events.
    pub fn new(events: Vec<FaultEvent>) -> Self {
        Self { events }
    }

    /// A plan with no faults (every die behaves).
    pub fn none() -> Self {
        Self::default()
    }

    /// Kill `die` for good at call `round`.
    pub fn kill(die: usize, round: usize) -> Self {
        Self::new(vec![FaultEvent { die, round, kind: FaultKind::Kill { until: None } }])
    }

    /// Kill `die` at call `round` and revive it at call `until`.
    pub fn kill_until(die: usize, round: usize, until: usize) -> Self {
        Self::new(vec![FaultEvent { die, round, kind: FaultKind::Kill { until: Some(until) } }])
    }

    /// Stall `die` (silent, no error) at call `round`.
    pub fn stall(die: usize, round: usize) -> Self {
        Self::new(vec![FaultEvent { die, round, kind: FaultKind::Stall }])
    }

    /// The fault governing `die`'s `call`-th `sweeps()` call, if any.
    pub fn fault_at(&self, die: usize, call: usize) -> Option<FaultKind> {
        self.events.iter().find_map(|e| {
            if e.die != die {
                return None;
            }
            match e.kind {
                FaultKind::Kill { until } => {
                    let dead = call >= e.round && until.is_none_or(|u| call < u);
                    dead.then_some(e.kind)
                }
                FaultKind::Stall | FaultKind::Delay { .. } => (call == e.round).then_some(e.kind),
            }
        })
    }

    /// A small random plan over `dies` dies and roughly `rounds`
    /// logical rounds, derived purely from `seed` — the generator the
    /// chaos matrix runs over. Only recoverable kinds are drawn (kills
    /// with and without revival, short delays); stalls are scripted
    /// explicitly where a test wants the timeout path.
    pub fn chaos(seed: u64, dies: usize, rounds: usize) -> Self {
        let mut rng = HostRng::new(seed ^ 0xFA_017);
        let n = 1 + rng.below(2);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let die = rng.below(dies.max(1));
            let round = rng.below(rounds.max(1));
            let kind = match rng.below(3) {
                0 => FaultKind::Kill { until: None },
                1 => FaultKind::Kill { until: Some(round + 1 + rng.below(rounds.max(1))) },
                _ => FaultKind::Delay { ms: 1 + rng.below(3) as u64 },
            };
            events.push(FaultEvent { die, round, kind });
        }
        Self::new(events)
    }

    /// Serialize the plan (for the CI artifact on a red chaos case).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    let (kind, arg) = match e.kind {
                        FaultKind::Kill { until: None } => ("kill", Json::Null),
                        FaultKind::Kill { until: Some(u) } => ("kill", Json::from(u)),
                        FaultKind::Stall => ("stall", Json::Null),
                        FaultKind::Delay { ms } => ("delay", Json::from(ms as usize)),
                    };
                    obj(vec![
                        ("die", Json::from(e.die)),
                        ("round", Json::from(e.round)),
                        ("kind", Json::from(kind)),
                        ("arg", arg),
                    ])
                })
                .collect(),
        )
    }

    /// Parse back what [`FaultPlan::to_json`] wrote.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut events = Vec::new();
        for e in v.as_arr()? {
            let die = e.req("die")?.as_usize()?;
            let round = e.req("round")?.as_usize()?;
            let arg = e.req("arg")?;
            let kind = match e.req("kind")?.as_str()? {
                "kill" => FaultKind::Kill {
                    until: match arg {
                        Json::Null => None,
                        other => Some(other.as_usize()?),
                    },
                },
                "stall" => FaultKind::Stall,
                "delay" => FaultKind::Delay { ms: arg.as_usize()? as u64 },
                other => bail!("unknown fault kind `{other}`"),
            };
            events.push(FaultEvent { die, round, kind });
        }
        Ok(Self::new(events))
    }
}

/// A [`Sampler`] wrapper that injects the faults a [`FaultPlan`]
/// scripts for one die. Every method delegates to the inner engine;
/// only `sweeps()` consults the plan (and counts the die's calls).
#[derive(Debug)]
pub struct FaultyChip<S> {
    /// The wrapped engine.
    pub inner: S,
    /// Which die of the plan this chip plays.
    pub die: usize,
    /// The fault schedule.
    pub plan: FaultPlan,
    calls: usize,
}

impl<S> FaultyChip<S> {
    /// Wrap `inner` as die `die` of `plan`.
    pub fn new(inner: S, die: usize, plan: FaultPlan) -> Self {
        Self { inner, die, plan, calls: 0 }
    }

    /// How many `sweeps()` calls this die has seen (failed ones count).
    pub fn calls(&self) -> usize {
        self.calls
    }
}

impl<S: Sampler> Sampler for FaultyChip<S> {
    fn load(&mut self, folded: &Folded) {
        self.inner.load(folded);
    }

    fn set_beta(&mut self, beta: f32) {
        self.inner.set_beta(beta);
    }

    fn set_betas(&mut self, betas: &[f32]) -> Result<()> {
        self.inner.set_betas(betas)
    }

    fn set_states(&mut self, states: &[Vec<i8>]) -> Result<()> {
        self.inner.set_states(states)
    }

    fn set_clamps(&mut self, clamps: &[(usize, i8)]) {
        self.inner.set_clamps(clamps);
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn sweeps(&mut self, n: usize) -> Result<()> {
        let call = self.calls;
        self.calls += 1;
        match self.plan.fault_at(self.die, call) {
            Some(FaultKind::Kill { .. }) => {
                bail!("injected fault: die {} is down (call {call})", self.die)
            }
            Some(FaultKind::Stall) => {
                std::thread::sleep(Duration::from_secs(3600));
                self.inner.sweeps(n)
            }
            Some(FaultKind::Delay { ms }) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.sweeps(n)
            }
            None => self.inner.sweeps(n),
        }
    }

    fn states(&self) -> Vec<Vec<i8>> {
        self.inner.states()
    }

    fn for_each_state(&self, f: &mut dyn FnMut(usize, &[i8])) {
        self.inner.for_each_state(f);
    }

    fn track_energies(&mut self, ledger: &EnergyLedger) -> Result<()> {
        self.inner.track_energies(ledger)
    }

    fn energies(&mut self) -> Result<Vec<f64>> {
        self.inner.energies()
    }

    fn randomize(&mut self, seed: u64) {
        self.inner.randomize(seed);
    }
}

impl<S: crate::learning::TrainableChip> crate::learning::TrainableChip for FaultyChip<S> {
    fn program_codes(&mut self, w: &crate::analog::ProgrammedWeights) -> Result<()> {
        self.inner.program_codes(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_window_gates_calls() {
        let plan = FaultPlan::kill_until(2, 3, 5);
        assert_eq!(plan.fault_at(2, 2), None);
        assert!(matches!(plan.fault_at(2, 3), Some(FaultKind::Kill { .. })));
        assert!(matches!(plan.fault_at(2, 4), Some(FaultKind::Kill { .. })));
        assert_eq!(plan.fault_at(2, 5), None);
        // other dies are untouched
        assert_eq!(plan.fault_at(1, 3), None);
    }

    #[test]
    fn permanent_kill_never_revives() {
        let plan = FaultPlan::kill(0, 1);
        assert_eq!(plan.fault_at(0, 0), None);
        for call in 1..100 {
            assert!(plan.fault_at(0, call).is_some());
        }
    }

    #[test]
    fn json_round_trips() {
        let plan = FaultPlan::new(vec![
            FaultEvent { die: 0, round: 4, kind: FaultKind::Kill { until: None } },
            FaultEvent { die: 1, round: 2, kind: FaultKind::Kill { until: Some(9) } },
            FaultEvent { die: 2, round: 0, kind: FaultKind::Stall },
            FaultEvent { die: 3, round: 7, kind: FaultKind::Delay { ms: 5 } },
        ]);
        let text = plan.to_json().to_string();
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn chaos_is_deterministic_and_in_range() {
        for seed in 0..32u64 {
            let a = FaultPlan::chaos(seed, 3, 10);
            let b = FaultPlan::chaos(seed, 3, 10);
            assert_eq!(a, b);
            assert!(!a.events.is_empty());
            for e in &a.events {
                assert!(e.die < 3);
                assert!(e.round < 10);
                assert!(!matches!(e.kind, FaultKind::Stall), "chaos never stalls");
            }
        }
    }

    #[test]
    fn faulty_chip_counts_and_fails() {
        use crate::sampler::SoftwareSampler;
        let plan = FaultPlan::kill_until(0, 1, 3);
        let mut chip = FaultyChip::new(SoftwareSampler::new(4, 7), 0, plan);
        assert!(chip.sweeps(1).is_ok());
        assert!(chip.sweeps(1).is_err());
        assert!(chip.sweeps(1).is_err());
        assert!(chip.sweeps(1).is_ok(), "revives at call 3");
        assert_eq!(chip.calls(), 4);
    }
}
