//! In-tree utility substrates that would normally come from crates.io —
//! the build is fully offline, so JSON, the TOML-lite config format, the
//! bench harness and the property-test driver are implemented here
//! (DESIGN.md §Dependencies).

pub mod bench;
pub mod fault;
pub mod json;
pub mod prop;
pub mod toml_lite;
