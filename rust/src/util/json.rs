//! Minimal JSON: recursive-descent parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); used for `artifacts/manifest.json`, the
//! golden topology files and experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted, for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------
    /// Object lookup; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object lookup that errors on a missing key.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    /// The value as a number, or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as an unsigned integer, or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    /// The value as a boolean, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// The value as a string, or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as an array, or a type error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The value as an object, or a type error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// An array of unsigned integers, or a type error.
    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ---------------------------------------------------------
    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience: build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at offset {}", c as char, self.i)
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at offset {}", self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected , or ] at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[[1,2],[3,[4,{"k":5}]]]"#).unwrap();
        let inner = v.as_arr().unwrap()[1].as_arr().unwrap()[1].as_arr().unwrap();
        assert_eq!(inner[1].req("k").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ↯""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ↯");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn integers_write_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"gibbs_b8": {"file": "gibbs_b8.hlo.txt", "inputs": [[8,448],[448,448]], "sweeps": 8}, "_meta": {"n_pad": 448}}"#;
        let v = Json::parse(text).unwrap();
        let e = v.req("gibbs_b8").unwrap();
        let inputs = e.req("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].usize_array().unwrap(), vec![8, 448]);
    }
}
