//! TOML-lite: the subset of TOML the config system needs — `[section]`
//! headers, `key = value` with string / float / integer / boolean values,
//! `#` comments. Nested tables via dotted section names.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// A number (TOML-lite does not distinguish int from float).
    Num(f64),
    /// A `true` / `false` literal.
    Bool(bool),
}

impl Value {
    /// The value as a number, or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as an unsigned integer, or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected unsigned integer, got {x}");
        }
        Ok(x as usize)
    }

    /// The value as a `u64`, or a type error.
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    /// The value as a string, or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a boolean, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// A parsed document: map from "section.key" (root keys have no prefix).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    /// Flattened `section.key` → value map.
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a TOML-lite document (line-oriented; errors carry the
    /// offending line number).
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`", lineno + 1);
            };
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, val);
        }
        Ok(Doc { entries })
    }

    /// Look a `section.key` entry up.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Read with default: `doc.f64_or("mismatch.sigma_dac", 0.05)`.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.get(key).map(|v| v.as_f64()).transpose().map(|o| o.unwrap_or(default))
    }

    /// [`Doc::f64_or`] for unsigned integers.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.get(key).map(|v| v.as_usize()).transpose().map(|o| o.unwrap_or(default))
    }

    /// [`Doc::f64_or`] for `u64`s.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        self.get(key).map(|v| v.as_u64()).transpose().map(|o| o.unwrap_or(default))
    }

    /// Read an optional string key (`None` when absent).
    pub fn str_opt(&self, key: &str) -> Result<Option<String>> {
        self.get(key).map(|v| v.as_str().map(str::to_string)).transpose()
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    match s.parse::<f64>() {
        Ok(x) => Ok(Value::Num(x)),
        Err(_) => bail!("cannot parse value `{s}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_types() {
        let doc = Doc::parse(
            "top = 1\n[mismatch]\nsigma_dac = 0.05 # comment\nname = \"chip0\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("mismatch.sigma_dac").unwrap().as_f64().unwrap(), 0.05);
        assert_eq!(doc.get("mismatch.name").unwrap().as_str().unwrap(), "chip0");
        assert!(doc.get("mismatch.flag").unwrap().as_bool().unwrap());
    }

    #[test]
    fn defaults_apply() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.f64_or("a.b", 2.5).unwrap(), 2.5);
        assert_eq!(doc.usize_or("x", 7).unwrap(), 7);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Doc::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("a = -3\nb = 1.5e-3\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_f64().unwrap(), -3.0);
        assert_eq!(doc.get("b").unwrap().as_f64().unwrap(), 1.5e-3);
        assert!(doc.get("a").unwrap().as_usize().is_err());
    }
}
