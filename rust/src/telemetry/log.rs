//! The crate's leveled diagnostic logger.
//!
//! One sink replaces the ad-hoc `eprintln!` diagnostics that used to be
//! scattered through `main.rs`, the sharded coordinator and the
//! training service: messages at or above the `PCHIP_LOG` threshold
//! (`debug|info|warn`, default `info`) go to stderr prefixed
//! `pchip[level]`, and — whenever telemetry recording is enabled —
//! every message (regardless of threshold) is also captured into the
//! trace event stream, so a `--trace-out` JSONL carries the membership
//! / failure narrative alongside the spans it explains.
//!
//! Use the [`crate::log_debug!`], [`crate::log_info!`] and
//! [`crate::log_warn!`] macros.

use std::sync::{Mutex, OnceLock};

/// Log severity, ordered `Debug < Info < Warn`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// High-volume diagnostics (per-link counter dumps, retry detail).
    Debug,
    /// Run narrative (membership changes, trace file locations).
    Info,
    /// Faults and degraded operation (die failures, timeouts).
    Warn,
}

impl Level {
    /// Lowercase name, as used in `PCHIP_LOG` and the stderr prefix.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
        }
    }

    /// Parse a `PCHIP_LOG` value (unknown values fall back to `Info`).
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "warn" | "warning" | "error" => Level::Warn,
            _ => Level::Info,
        }
    }
}

/// The stderr threshold: messages below it are not printed (they are
/// still recorded into the trace stream when telemetry is enabled).
/// Read once from `PCHIP_LOG`; defaults to [`Level::Info`].
pub fn threshold() -> Level {
    static THRESHOLD: OnceLock<Level> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        std::env::var("PCHIP_LOG").map(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

/// Whether a message at `level` would reach stderr.
pub fn stderr_enabled(level: Level) -> bool {
    level >= threshold()
}

/// One captured log record (trace event stream).
#[derive(Debug, Clone, PartialEq)]
pub struct LogEvent {
    /// Timestamp on the [`super::now_ns`] clock.
    pub ts_ns: u64,
    /// Severity.
    pub level: Level,
    /// Formatted message.
    pub msg: String,
    /// Recording thread's registry index.
    pub tid: u32,
}

/// Captured events are low-rate (membership changes, failures), so a
/// plain mutex-guarded vec is fine — this is not a recording hot path.
fn events() -> &'static Mutex<Vec<LogEvent>> {
    static EVENTS: OnceLock<Mutex<Vec<LogEvent>>> = OnceLock::new();
    EVENTS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Cap on captured events; beyond it new events are dropped (the drop
/// count is visible as the gap in trace sequence, and a run that logs
/// this much has bigger problems).
const MAX_EVENTS: usize = 65_536;

/// Route one message: stderr when at/above the [`threshold`], trace
/// capture when telemetry is enabled. Prefer the `log_*!` macros.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    let to_stderr = stderr_enabled(level);
    let to_trace = super::enabled();
    if !to_stderr && !to_trace {
        return;
    }
    let msg = std::fmt::format(args);
    if to_stderr {
        eprintln!("pchip[{}] {}", level.as_str(), msg);
    }
    if to_trace {
        let ev = LogEvent {
            ts_ns: super::now_ns(),
            level,
            msg,
            tid: super::registry::current_tid(),
        };
        let mut v = events().lock().unwrap_or_else(|e| e.into_inner());
        if v.len() < MAX_EVENTS {
            v.push(ev);
        }
    }
}

/// Copy of every captured event (exporters).
pub fn events_snapshot() -> Vec<LogEvent> {
    events().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Drop all captured events (see [`super::reset`]).
pub(super) fn clear_events() {
    events().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Log at debug level (suppressed on stderr unless `PCHIP_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::telemetry::log::log($crate::telemetry::log::Level::Debug, format_args!($($t)*))
    };
}

/// Log at info level (the default stderr threshold).
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::telemetry::log::log($crate::telemetry::log::Level::Info, format_args!($($t)*))
    };
}

/// Log at warn level (always on stderr under every `PCHIP_LOG` value).
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::telemetry::log::log($crate::telemetry::log::Level::Warn, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parse() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert_eq!(Level::parse("debug"), Level::Debug);
        assert_eq!(Level::parse("WARN"), Level::Warn);
        assert_eq!(Level::parse("error"), Level::Warn);
        assert_eq!(Level::parse("nonsense"), Level::Info);
    }
}
