//! Unified runtime telemetry: spans, per-die counters, trace export.
//!
//! An always-compiled-in, **off-by-default** instrumentation plane for
//! the whole gang stack. When disabled (the default) every
//! instrumentation point is one relaxed atomic load and a branch — the
//! hot paths stay bit-identical and effectively free (guarded by the
//! `telemetry_on` arm in `benches/sampler_hotpath.rs`). When enabled
//! (CLI `--trace-out` / `--trace-perfetto`, env `PCHIP_TELEMETRY=1`, or
//! [`set_enabled`]) each thread lazily registers a private shard of
//! atomic counters, fixed-bucket duration histograms, and a span ring
//! buffer; readers merge shards on demand, mirroring the
//! `GradAccum` / `SwapStats` merge-on-read idiom — no lock is ever
//! taken on a recording path.
//!
//! The pieces:
//!
//! * [`registry`] — interned counter/histogram names, the per-thread
//!   [`registry::ThreadShard`]s, and merged [`registry::Snapshot`]s.
//! * [`crate::span!`] — lightweight scope timing; each completed span
//!   lands in the owning thread's ring buffer *and* feeds the duration
//!   histogram of the same name (so `barrier_wait` p50/p99 come free).
//! * [`export`] — two exporters over the same recorded state: a JSONL
//!   event stream and a Chrome/Perfetto `trace_event` JSON that opens
//!   directly in [ui.perfetto.dev](https://ui.perfetto.dev).
//! * [`summary::RunTelemetry`] — the per-run rollup (flips/s per die,
//!   barrier-wait p50/p99, swap-phase latency, probe/retry counts, link
//!   delivery totals) attached to `ShardedRun` / `EpochStats` and
//!   printed by `pchip report`.
//! * [`log`] — the leveled logger (`PCHIP_LOG=debug|info|warn`) that
//!   replaced the ad-hoc `eprintln!` diagnostics; records route into
//!   the telemetry event stream when tracing is on.
//!
//! Per-die attribution: a die/shard worker thread labels itself once
//! with [`set_die`]; every counter increment, histogram record and span
//! from that thread is tagged with the label. Threads without a label
//! (the CLI main thread, pool workers) aggregate under "no die".
//!
//! `docs/OBSERVABILITY.md` is the practitioner guide.

pub mod export;
pub mod log;
pub mod registry;
pub mod summary;

pub use registry::{Id, Snapshot};
pub use summary::RunTelemetry;

use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The global enable flag. Relaxed is enough: enabling mid-run only
/// affects *when* threads start recording, never memory safety.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether telemetry recording is on. This is the whole cost of a
/// disabled instrumentation point (one relaxed load + branch).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Enable telemetry if `PCHIP_TELEMETRY=1|true` is set (called once
/// from `main`; library embedders call [`set_enabled`] directly).
pub fn init_from_env() {
    if matches!(std::env::var("PCHIP_TELEMETRY").as_deref(), Ok("1") | Ok("true")) {
        set_enabled(true);
    }
}

// ---- monotonic clock ---------------------------------------------------

struct Epoch {
    started: Instant,
    /// Wall-clock at process start, for trace metadata only.
    unix_ms: u128,
}

fn epoch() -> &'static Epoch {
    static EPOCH: OnceLock<Epoch> = OnceLock::new();
    EPOCH.get_or_init(|| Epoch {
        started: Instant::now(),
        unix_ms: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0),
    })
}

/// Monotonic nanoseconds since the process's telemetry epoch.
#[inline]
pub fn now_ns() -> u64 {
    epoch().started.elapsed().as_nanos() as u64
}

/// Wall-clock milliseconds (unix) at the telemetry epoch — trace
/// metadata so exported timestamps can be anchored to real time.
pub fn epoch_unix_ms() -> u128 {
    epoch().unix_ms
}

// ---- per-thread die label ----------------------------------------------

thread_local! {
    /// This thread's die label + 1 (0 = unlabeled), mirrored into its
    /// registry shard when one exists.
    static DIE: AtomicI64 = const { AtomicI64::new(0) };
}

/// Label the current thread as belonging to die/shard `die`. Called
/// once by die-owning worker threads (shard workers, train workers);
/// every subsequent record from this thread carries the label.
pub fn set_die(die: usize) {
    DIE.with(|d| d.store(die as i64 + 1, Ordering::Relaxed));
    registry::relabel_current_shard(die as i64 + 1);
}

/// Remove the current thread's die label.
pub fn clear_die() {
    DIE.with(|d| d.store(0, Ordering::Relaxed));
    registry::relabel_current_shard(0);
}

/// The current thread's die label, if any.
#[inline]
pub fn current_die() -> Option<usize> {
    let raw = DIE.with(|d| d.load(Ordering::Relaxed));
    (raw > 0).then(|| raw as usize - 1)
}

// ---- spans -------------------------------------------------------------

/// An open span; records one complete (begin, duration) record into the
/// owning thread's ring buffer — and the same-named duration histogram —
/// when dropped. Obtained via the [`crate::span!`] macro; a guard
/// created while telemetry is disabled is inert (no clock read, no
/// allocation).
#[must_use = "a span measures the scope it is bound to; drop it at the end"]
pub struct SpanGuard {
    /// `None` when telemetry was disabled at entry.
    armed: Option<ArmedSpan>,
}

struct ArmedSpan {
    name: Id,
    /// Die override (+1, 0 = use the thread label at drop time).
    die: i64,
    start_ns: u64,
}

impl SpanGuard {
    /// Open a span named `name`, attributed to the current thread's die
    /// label (if any).
    #[inline]
    pub fn enter(name: Id) -> SpanGuard {
        if !enabled() {
            return SpanGuard { armed: None };
        }
        SpanGuard { armed: Some(ArmedSpan { name, die: 0, start_ns: now_ns() }) }
    }

    /// Open a span with an explicit die label (overrides the thread's).
    #[inline]
    pub fn enter_with_die(name: Id, die: usize) -> SpanGuard {
        if !enabled() {
            return SpanGuard { armed: None };
        }
        SpanGuard { armed: Some(ArmedSpan { name, die: die as i64 + 1, start_ns: now_ns() }) }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some(armed) = self.armed.take() {
            let dur = now_ns().saturating_sub(armed.start_ns);
            let die = if armed.die > 0 {
                armed.die
            } else {
                DIE.with(|d| d.load(Ordering::Relaxed))
            };
            registry::record_span(armed.name, die, armed.start_ns, dur);
            registry::record_ns(armed.name, dur);
        }
    }
}

/// Open a [`SpanGuard`] for the enclosing scope.
///
/// The span name is interned once per call site (a `static OnceLock`),
/// so steady-state cost is a relaxed enable check plus, when enabled,
/// two clock reads and a handful of relaxed atomic stores.
///
/// ```
/// # fn barrier_wait() {}
/// {
///     let _span = pchip::span!("swap_phase");
///     barrier_wait(); // ... timed work ...
/// } // record lands here
/// let _tagged = pchip::span!("sweep_phase", die = 3);
/// ```
#[macro_export]
macro_rules! span {
    ($name:literal) => {{
        static __PCHIP_SPAN_ID: ::std::sync::OnceLock<$crate::telemetry::Id> =
            ::std::sync::OnceLock::new();
        $crate::telemetry::SpanGuard::enter(
            *__PCHIP_SPAN_ID.get_or_init(|| $crate::telemetry::registry::intern($name)),
        )
    }};
    ($name:literal, die = $die:expr) => {{
        static __PCHIP_SPAN_ID: ::std::sync::OnceLock<$crate::telemetry::Id> =
            ::std::sync::OnceLock::new();
        $crate::telemetry::SpanGuard::enter_with_die(
            *__PCHIP_SPAN_ID.get_or_init(|| $crate::telemetry::registry::intern($name)),
            $die,
        )
    }};
}

/// Add `n` to the named counter (interned once per call site). The
/// counter is attributed to the calling thread's die label.
///
/// ```
/// pchip::counter_add!("flips", 440);
/// ```
#[macro_export]
macro_rules! counter_add {
    ($name:literal, $n:expr) => {{
        if $crate::telemetry::enabled() {
            static __PCHIP_CTR_ID: ::std::sync::OnceLock<$crate::telemetry::Id> =
                ::std::sync::OnceLock::new();
            $crate::telemetry::registry::add(
                *__PCHIP_CTR_ID.get_or_init(|| $crate::telemetry::registry::intern($name)),
                $n,
            );
        }
    }};
}

/// Reset all recorded telemetry (counters, histograms, span rings, log
/// events) to zero across every registered thread shard. For tests and
/// long-lived tools that scope recording to one run; the interned name
/// table and thread registrations survive.
pub fn reset() {
    registry::reset();
    log::clear_events();
}

#[cfg(test)]
mod tests {
    use super::*;

    // Telemetry state is process-global; every test that enables it
    // must hold this lock (shared with tests/telemetry.rs via its own
    // static — unit tests and integration tests run in separate
    // processes, so one lock per process suffices).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_span_is_inert() {
        let _g = lock();
        set_enabled(false);
        reset();
        {
            let _s = crate::span!("unit_inert");
        }
        crate::counter_add!("unit_inert_ctr", 7);
        let snap = registry::snapshot();
        assert!(snap.counters.is_empty());
        assert!(snap.hists.is_empty());
    }

    #[test]
    fn counters_attribute_to_die_label() {
        let _g = lock();
        set_enabled(true);
        reset();
        // Die attribution is per *thread* (a worker labels itself once
        // at spawn), so the labeled counting runs on its own thread.
        std::thread::spawn(|| {
            set_die(4);
            crate::counter_add!("unit_flips", 10);
            crate::counter_add!("unit_flips", 5);
        })
        .join()
        .unwrap();
        clear_die();
        crate::counter_add!("unit_flips", 3);
        let snap = registry::snapshot();
        assert_eq!(snap.counter("unit_flips", Some(4)), 15);
        assert_eq!(snap.counter("unit_flips", None), 3);
        assert_eq!(snap.counter_total("unit_flips"), 18);
        set_enabled(false);
    }

    #[test]
    fn span_records_ring_and_histogram() {
        let _g = lock();
        set_enabled(true);
        reset();
        for _ in 0..32 {
            let _s = crate::span!("unit_span", die = 2);
        }
        let snap = registry::snapshot();
        let spans = registry::spans_snapshot();
        let mine: Vec<_> = spans
            .iter()
            .filter(|s| registry::name_of(s.name).as_deref() == Some("unit_span"))
            .collect();
        assert_eq!(mine.len(), 32);
        assert!(mine.iter().all(|s| s.die == Some(2)));
        // the histogram is attributed to the recording thread (here
        // unlabeled), independent of the span's die override
        let hist = snap.hist_total("unit_span").expect("histogram fed by span");
        assert_eq!(hist.count, 32);
        set_enabled(false);
    }

    #[test]
    fn current_die_roundtrip() {
        let _g = lock();
        assert_eq!(current_die(), None);
        set_die(7);
        assert_eq!(current_die(), Some(7));
        clear_die();
        assert_eq!(current_die(), None);
    }
}
