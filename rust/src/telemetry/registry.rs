//! The lock-free telemetry registry: interned names, per-thread shards
//! of atomic counters / fixed-bucket duration histograms / span rings,
//! merged on read.
//!
//! Writers never contend: each thread owns one [`ThreadShard`] (created
//! lazily on its first enabled record) and touches only relaxed atomics
//! inside it. Readers ([`snapshot`], [`spans_snapshot`]) walk the global
//! shard list and sum — the same owner-writes / reader-merges idiom as
//! `GradAccum` all-reduce and `SwapStats` folding, lifted to runtime
//! metrics.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Counter / histogram / span name slots per thread shard. Interning
/// more distinct names than this is allowed (the name table is
/// unbounded) but records beyond the slot capacity are dropped.
pub const MAX_IDS: usize = 128;

/// Histogram bucket count. Bucket `i` holds durations in
/// `[2^(i-1), 2^i)` nanoseconds, so 40 buckets cover ~9 minutes.
pub const HIST_BUCKETS: usize = 40;

/// Span records retained per thread (a ring: oldest are overwritten).
pub const SPAN_RING: usize = 1 << 14;

/// An interned telemetry name (counter, histogram and span names share
/// one table; a span feeds the histogram of the same id).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Id(u32);

fn names() -> &'static Mutex<Vec<&'static str>> {
    static NAMES: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern `name`, returning a stable [`Id`]. Idempotent; call sites
/// cache the result in a `static OnceLock` (the `span!` /
/// `counter_add!` macros do this automatically).
pub fn intern(name: &'static str) -> Id {
    let mut t = names().lock().unwrap_or_else(|e| e.into_inner());
    if let Some(pos) = t.iter().position(|&n| n == name) {
        return Id(pos as u32);
    }
    t.push(name);
    Id((t.len() - 1) as u32)
}

/// The name interned as `id`, if it exists.
pub fn name_of(id: Id) -> Option<String> {
    let t = names().lock().unwrap_or_else(|e| e.into_inner());
    t.get(id.0 as usize).map(|s| s.to_string())
}

// ---- per-thread shard --------------------------------------------------

/// One span slot: `meta` packs `(name_id + 1) << 32 | die_raw`
/// (`meta == 0` after reset means "empty"). Written only by the owning
/// thread; read by exporters when the run is quiescent.
struct SpanSlot {
    meta: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
}

/// One thread's private telemetry storage. All fields are written with
/// relaxed ordering by the owner and summed by readers.
pub struct ThreadShard {
    /// Sequential registration index (stable per thread).
    tid: u32,
    /// OS thread name at registration time (for trace display).
    name: String,
    /// Die label + 1 (0 = unlabeled); kept in sync with
    /// [`super::set_die`].
    die: AtomicI64,
    /// One slot per interned name.
    counters: Vec<AtomicU64>,
    /// Flattened `[MAX_IDS][HIST_BUCKETS + 2]`: buckets, then count,
    /// then sum-of-ns per name.
    hists: Vec<AtomicU64>,
    /// Span ring storage.
    spans: Vec<SpanSlot>,
    /// Total spans ever recorded; `head % SPAN_RING` is the next slot.
    span_head: AtomicU64,
}

const HIST_STRIDE: usize = HIST_BUCKETS + 2;

impl ThreadShard {
    fn new(tid: u32, die_raw: i64) -> Self {
        Self {
            tid,
            name: std::thread::current().name().unwrap_or("?").to_string(),
            die: AtomicI64::new(die_raw),
            counters: (0..MAX_IDS).map(|_| AtomicU64::new(0)).collect(),
            hists: (0..MAX_IDS * HIST_STRIDE).map(|_| AtomicU64::new(0)).collect(),
            spans: (0..SPAN_RING)
                .map(|_| SpanSlot {
                    meta: AtomicU64::new(0),
                    start_ns: AtomicU64::new(0),
                    dur_ns: AtomicU64::new(0),
                })
                .collect(),
            span_head: AtomicU64::new(0),
        }
    }

    fn die_label(&self) -> Option<usize> {
        let raw = self.die.load(Ordering::Relaxed);
        (raw > 0).then(|| raw as usize - 1)
    }
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadShard>>> {
    static REG: OnceLock<Mutex<Vec<Arc<ThreadShard>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static SHARD: std::cell::OnceCell<Arc<ThreadShard>> = const { std::cell::OnceCell::new() };
}

fn shard() -> Arc<ThreadShard> {
    SHARD.with(|s| {
        s.get_or_init(|| {
            let die_raw = super::current_die().map(|d| d as i64 + 1).unwrap_or(0);
            let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
            let sh = Arc::new(ThreadShard::new(reg.len() as u32, die_raw));
            reg.push(sh.clone());
            sh
        })
        .clone()
    })
}

/// Update the registered shard's die label after [`super::set_die`] /
/// [`super::clear_die`] — only if this thread already has a shard (a
/// label set before the first record is picked up at shard creation).
pub(super) fn relabel_current_shard(die_raw: i64) {
    SHARD.with(|s| {
        if let Some(sh) = s.get() {
            sh.die.store(die_raw, Ordering::Relaxed);
        }
    });
}

/// The calling thread's registration index (creates the shard).
pub fn current_tid() -> u32 {
    shard().tid
}

/// Add `n` to counter `id` on the calling thread's shard. Callers gate
/// on [`super::enabled`] (the `counter_add!` macro does).
#[inline]
pub fn add(id: Id, n: u64) {
    let sh = shard();
    if let Some(slot) = sh.counters.get(id.0 as usize) {
        slot.fetch_add(n, Ordering::Relaxed);
    }
}

/// Bucket index for a duration: `[2^(i-1), 2^i)` ns, clamped.
#[inline]
fn bucket_of(ns: u64) -> usize {
    ((64 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Record a duration into histogram `id` on the calling thread's shard.
#[inline]
pub fn record_ns(id: Id, ns: u64) {
    let sh = shard();
    let base = id.0 as usize * HIST_STRIDE;
    if base + HIST_STRIDE <= sh.hists.len() {
        sh.hists[base + bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        sh.hists[base + HIST_BUCKETS].fetch_add(1, Ordering::Relaxed);
        sh.hists[base + HIST_BUCKETS + 1].fetch_add(ns, Ordering::Relaxed);
    }
}

/// Record a completed span into the calling thread's ring.
#[inline]
pub fn record_span(name: Id, die_raw: i64, start_ns: u64, dur_ns: u64) {
    let sh = shard();
    let head = sh.span_head.fetch_add(1, Ordering::Relaxed);
    let slot = &sh.spans[(head % SPAN_RING as u64) as usize];
    slot.meta.store(
        ((name.0 as u64 + 1) << 32) | (die_raw.clamp(0, u32::MAX as i64) as u64),
        Ordering::Relaxed,
    );
    slot.start_ns.store(start_ns, Ordering::Relaxed);
    slot.dur_ns.store(dur_ns, Ordering::Relaxed);
}

// ---- reading -----------------------------------------------------------

/// A merged histogram: power-of-two-ns buckets plus exact count/sum.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HistData {
    /// Occupancy per power-of-two bucket (see [`HIST_BUCKETS`]).
    pub buckets: Vec<u64>,
    /// Number of recorded durations.
    pub count: u64,
    /// Sum of recorded durations in nanoseconds.
    pub sum_ns: u64,
}

impl HistData {
    fn zeroed() -> Self {
        Self { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ns: 0 }
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &HistData) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; HIST_BUCKETS];
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    /// Approximate quantile in nanoseconds: the upper bound of the
    /// bucket containing the `q`-th recorded duration (so p99 is an
    /// upper estimate, never below the true value by more than 2×).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (HIST_BUCKETS - 1)
    }

    /// Exact mean duration in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// `self - earlier`, element-wise saturating (for run-scoped diffs).
    pub fn diff(&self, earlier: &HistData) -> HistData {
        let mut out = self.clone();
        for (a, b) in out.buckets.iter_mut().zip(&earlier.buckets) {
            *a = a.saturating_sub(*b);
        }
        out.count = out.count.saturating_sub(earlier.count);
        out.sum_ns = out.sum_ns.saturating_sub(earlier.sum_ns);
        out
    }
}

/// A merged, keyed view of every shard's counters and histograms at one
/// instant. Keys are `(name, die label)`.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// Counter totals by `(name, die)`.
    pub counters: BTreeMap<(String, Option<usize>), u64>,
    /// Histograms by `(name, die)`.
    pub hists: BTreeMap<(String, Option<usize>), HistData>,
}

impl Snapshot {
    /// One counter's value for one die (0 when absent).
    pub fn counter(&self, name: &str, die: Option<usize>) -> u64 {
        self.counters.get(&(name.to_string(), die)).copied().unwrap_or(0)
    }

    /// One counter summed over all die labels.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters.iter().filter(|((n, _), _)| n == name).map(|(_, v)| v).sum()
    }

    /// One histogram for one die, if recorded.
    pub fn hist(&self, name: &str, die: Option<usize>) -> Option<&HistData> {
        self.hists.get(&(name.to_string(), die))
    }

    /// One histogram merged over all die labels.
    pub fn hist_total(&self, name: &str) -> Option<HistData> {
        let mut out: Option<HistData> = None;
        for ((n, _), h) in &self.hists {
            if n == name {
                out.get_or_insert_with(HistData::zeroed).merge(h);
            }
        }
        out
    }

    /// Per-die values of one counter, sorted by die (unlabeled first).
    pub fn counter_by_die(&self, name: &str) -> Vec<(Option<usize>, u64)> {
        self.counters
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|((_, d), v)| (*d, *v))
            .collect()
    }

    /// `self - earlier` per key, dropping keys that reach zero — the
    /// run-scoped view used by [`super::RunTelemetry::capture`].
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        let mut out = Snapshot::default();
        for (k, v) in &self.counters {
            let base = earlier.counters.get(k).copied().unwrap_or(0);
            let d = v.saturating_sub(base);
            if d > 0 {
                out.counters.insert(k.clone(), d);
            }
        }
        for (k, h) in &self.hists {
            let d = match earlier.hists.get(k) {
                Some(e) => h.diff(e),
                None => h.clone(),
            };
            if d.count > 0 {
                out.hists.insert(k.clone(), d);
            }
        }
        out
    }
}

/// Merge every thread shard's counters and histograms into a
/// [`Snapshot`]. Cheap enough for per-epoch capture; zero-valued
/// entries are skipped so an idle registry yields empty maps.
pub fn snapshot() -> Snapshot {
    let shards: Vec<Arc<ThreadShard>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    let name_table: Vec<String> = {
        let t = names().lock().unwrap_or_else(|e| e.into_inner());
        t.iter().map(|s| s.to_string()).collect()
    };
    let mut snap = Snapshot::default();
    for sh in &shards {
        let die = sh.die_label();
        for (i, slot) in sh.counters.iter().enumerate().take(name_table.len()) {
            let v = slot.load(Ordering::Relaxed);
            if v > 0 {
                *snap.counters.entry((name_table[i].clone(), die)).or_insert(0) += v;
            }
        }
        for i in 0..name_table.len().min(MAX_IDS) {
            let base = i * HIST_STRIDE;
            let count = sh.hists[base + HIST_BUCKETS].load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            let mut h = HistData::zeroed();
            for (b, slot) in h.buckets.iter_mut().zip(&sh.hists[base..base + HIST_BUCKETS]) {
                *b = slot.load(Ordering::Relaxed);
            }
            h.count = count;
            h.sum_ns = sh.hists[base + HIST_BUCKETS + 1].load(Ordering::Relaxed);
            snap.hists
                .entry((name_table[i].clone(), die))
                .or_insert_with(HistData::zeroed)
                .merge(&h);
        }
    }
    snap
}

/// One completed span, as read back from a thread's ring.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    /// Interned span name.
    pub name: Id,
    /// Die label the span was attributed to.
    pub die: Option<usize>,
    /// Owning thread's registration index.
    pub tid: u32,
    /// Owning thread's OS name at registration.
    pub thread: String,
    /// Begin timestamp ([`super::now_ns`] clock).
    pub start_ns: u64,
    /// Wall duration.
    pub dur_ns: u64,
}

/// Copy every retained span out of every thread ring (read-only; call
/// when the instrumented run is quiescent — records being overwritten
/// concurrently may tear). Returns spans in per-thread recording order.
pub fn spans_snapshot() -> Vec<SpanRec> {
    let shards: Vec<Arc<ThreadShard>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut out = Vec::new();
    for sh in &shards {
        let head = sh.span_head.load(Ordering::Relaxed);
        let kept = head.min(SPAN_RING as u64);
        let first = head - kept; // oldest retained record index
        for rec in first..head {
            let slot = &sh.spans[(rec % SPAN_RING as u64) as usize];
            let meta = slot.meta.load(Ordering::Relaxed);
            if meta == 0 {
                continue;
            }
            let name = Id((meta >> 32) as u32 - 1);
            let die_raw = (meta & 0xffff_ffff) as i64;
            out.push(SpanRec {
                name,
                die: (die_raw > 0).then(|| die_raw as usize - 1),
                tid: sh.tid,
                thread: sh.name.clone(),
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
            });
        }
    }
    out
}

/// Spans lost to ring overwrite across all threads (exported as trace
/// metadata so truncation is visible rather than silent).
pub fn spans_overwritten() -> u64 {
    let shards: Vec<Arc<ThreadShard>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    shards
        .iter()
        .map(|sh| sh.span_head.load(Ordering::Relaxed).saturating_sub(SPAN_RING as u64))
        .sum()
}

/// Registered threads as `(tid, name, die)` rows (trace metadata).
pub fn threads() -> Vec<(u32, String, Option<usize>)> {
    let shards: Vec<Arc<ThreadShard>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    shards.iter().map(|sh| (sh.tid, sh.name.clone(), sh.die_label())).collect()
}

/// Zero every shard's counters, histograms and span ring heads (see
/// [`super::reset`]). Racy-but-safe against concurrent recorders.
pub(super) fn reset() {
    let shards: Vec<Arc<ThreadShard>> =
        registry().lock().unwrap_or_else(|e| e.into_inner()).clone();
    for sh in &shards {
        for c in &sh.counters {
            c.store(0, Ordering::Relaxed);
        }
        for h in &sh.hists {
            h.store(0, Ordering::Relaxed);
        }
        for s in &sh.spans {
            s.meta.store(0, Ordering::Relaxed);
        }
        sh.span_head.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let a = intern("unit_reg_name");
        let b = intern("unit_reg_name");
        assert_eq!(a, b);
        assert_eq!(name_of(a).as_deref(), Some("unit_reg_name"));
    }

    #[test]
    fn bucket_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn hist_quantiles_bracket_the_data() {
        let mut h = HistData::zeroed();
        // 99 fast records (~1µs) and 1 slow (~1ms)
        for _ in 0..99 {
            h.buckets[bucket_of(1_000)] += 1;
        }
        h.buckets[bucket_of(1_000_000)] += 1;
        h.count = 100;
        h.sum_ns = 99 * 1_000 + 1_000_000;
        assert!(h.quantile_ns(0.5) >= 1_000 && h.quantile_ns(0.5) < 4_000);
        assert!(h.quantile_ns(0.99) < 1_000_000); // 99th record is still fast
        assert!(h.quantile_ns(1.0) >= 1_000_000);
        assert!((h.mean_ns() - 10_990.0).abs() < 1.0);
    }

    #[test]
    fn hist_diff_subtracts() {
        let mut a = HistData::zeroed();
        a.buckets[3] = 10;
        a.count = 10;
        a.sum_ns = 80;
        let mut b = HistData::zeroed();
        b.buckets[3] = 4;
        b.count = 4;
        b.sum_ns = 30;
        let d = a.diff(&b);
        assert_eq!(d.buckets[3], 6);
        assert_eq!(d.count, 6);
        assert_eq!(d.sum_ns, 50);
    }
}
