//! Trace exporters: a JSONL event stream and a Chrome/Perfetto
//! `trace_event` JSON, both rendered from the same recorded registry
//! state.
//!
//! **JSONL** (`--trace-out run.jsonl`): one JSON object per line, in
//! timestamp order. Record types: `meta` (clock anchor, thread table,
//! ring truncation), `counter`, `hist`, `span_begin`/`span_end`
//! (synthesized in balanced pairs from the complete-span ring records),
//! `log`, `summary` (the [`RunTelemetry`] rollup), and — on `pchip
//! temper --trace-out` — `energy` rows from the run's
//! [`crate::metrics::EnergyTrace`]. `pchip report FILE` reads this
//! stream back.
//!
//! **Perfetto** (`--trace-perfetto out.json`): the Chrome
//! `trace_event` array format — `ph:"X"` complete events (µs
//! timestamps) plus `ph:"M"` thread-name metadata — which loads
//! directly in `ui.perfetto.dev` or `chrome://tracing` as a per-thread
//! flame chart of sweep/swap/epoch phases.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

use super::registry::{self, SpanRec};
use super::summary::RunTelemetry;

/// Everything recorded so far, as ordered JSONL lines (without trailing
/// newlines). `summary` and `extra` rows (e.g. energy-trace rows) are
/// appended after the event stream.
pub fn jsonl_lines(summary: Option<&RunTelemetry>, extra: &[Json]) -> Vec<String> {
    let mut lines = Vec::new();
    let threads = registry::threads();
    lines.push(
        obj(vec![
            ("type", Json::from("meta")),
            ("version", Json::from(1.0)),
            ("epoch_unix_ms", Json::from(super::epoch_unix_ms() as f64)),
            ("spans_overwritten", Json::from(registry::spans_overwritten() as f64)),
            (
                "threads",
                Json::Arr(
                    threads
                        .iter()
                        .map(|(tid, name, die)| {
                            obj(vec![
                                ("tid", Json::from(*tid as f64)),
                                ("name", Json::from(name.as_str())),
                                ("die", die.map(|d| Json::from(d as f64)).unwrap_or(Json::Null)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string(),
    );

    let snap = registry::snapshot();
    for ((name, die), v) in &snap.counters {
        lines.push(
            obj(vec![
                ("type", Json::from("counter")),
                ("name", Json::from(name.as_str())),
                ("die", die.map(|d| Json::from(d as f64)).unwrap_or(Json::Null)),
                ("value", Json::from(*v as f64)),
            ])
            .to_string(),
        );
    }
    for ((name, die), h) in &snap.hists {
        lines.push(
            obj(vec![
                ("type", Json::from("hist")),
                ("name", Json::from(name.as_str())),
                ("die", die.map(|d| Json::from(d as f64)).unwrap_or(Json::Null)),
                ("count", Json::from(h.count as f64)),
                ("sum_ns", Json::from(h.sum_ns as f64)),
                ("p50_ns", Json::from(h.quantile_ns(0.50) as f64)),
                ("p99_ns", Json::from(h.quantile_ns(0.99) as f64)),
            ])
            .to_string(),
        );
    }

    // Span ring records become balanced begin/end pairs, merged with
    // log events into one timestamp-ordered stream.
    enum Ev {
        Begin(SpanRec),
        End(SpanRec),
        Log(super::log::LogEvent),
    }
    let mut evs: Vec<(u64, Ev)> = Vec::new();
    for s in registry::spans_snapshot() {
        evs.push((s.start_ns, Ev::Begin(s.clone())));
        evs.push((s.start_ns + s.dur_ns, Ev::End(s)));
    }
    for l in super::log::events_snapshot() {
        evs.push((l.ts_ns, Ev::Log(l)));
    }
    evs.sort_by_key(|(ts, _)| *ts);
    for (_, ev) in evs {
        let line = match ev {
            Ev::Begin(s) => obj(vec![
                ("type", Json::from("span_begin")),
                ("name", Json::from(registry::name_of(s.name).unwrap_or_default())),
                ("die", s.die.map(|d| Json::from(d as f64)).unwrap_or(Json::Null)),
                ("tid", Json::from(s.tid as f64)),
                ("thread", Json::from(s.thread.as_str())),
                ("ts_ns", Json::from(s.start_ns as f64)),
            ]),
            Ev::End(s) => obj(vec![
                ("type", Json::from("span_end")),
                ("name", Json::from(registry::name_of(s.name).unwrap_or_default())),
                ("tid", Json::from(s.tid as f64)),
                ("ts_ns", Json::from((s.start_ns + s.dur_ns) as f64)),
            ]),
            Ev::Log(l) => obj(vec![
                ("type", Json::from("log")),
                ("level", Json::from(l.level.as_str())),
                ("msg", Json::from(l.msg.as_str())),
                ("tid", Json::from(l.tid as f64)),
                ("ts_ns", Json::from(l.ts_ns as f64)),
            ]),
        };
        lines.push(line.to_string());
    }

    if let Some(t) = summary {
        let row = obj(vec![("type", Json::from("summary")), ("summary", t.to_json())]);
        lines.push(row.to_string());
    }
    for row in extra {
        lines.push(row.to_string());
    }
    lines
}

/// Write the JSONL event stream to `path`.
pub fn write_jsonl(path: &Path, summary: Option<&RunTelemetry>, extra: &[Json]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    for line in jsonl_lines(summary, extra) {
        writeln!(f, "{line}")?;
    }
    Ok(())
}

/// Build the Chrome `trace_event` JSON document.
pub fn perfetto_json() -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (tid, name, die) in registry::threads() {
        let label = match die {
            Some(d) => format!("{name} (die {d})"),
            None => name,
        };
        events.push(obj(vec![
            ("ph", Json::from("M")),
            ("name", Json::from("thread_name")),
            ("pid", Json::from(1.0)),
            ("tid", Json::from(tid as f64)),
            ("args", obj(vec![("name", Json::from(label))])),
        ]));
    }
    for s in registry::spans_snapshot() {
        let mut args = vec![];
        if let Some(d) = s.die {
            args.push(("die", Json::from(d as f64)));
        }
        events.push(obj(vec![
            ("ph", Json::from("X")),
            ("name", Json::from(registry::name_of(s.name).unwrap_or_default())),
            ("cat", Json::from("pchip")),
            ("pid", Json::from(1.0)),
            ("tid", Json::from(s.tid as f64)),
            ("ts", Json::from(s.start_ns as f64 / 1_000.0)),
            ("dur", Json::from(s.dur_ns as f64 / 1_000.0)),
            ("args", obj(args)),
        ]));
    }
    for l in super::log::events_snapshot() {
        events.push(obj(vec![
            ("ph", Json::from("i")),
            ("s", Json::from("g")),
            ("name", Json::from(format!("[{}] {}", l.level.as_str(), l.msg))),
            ("cat", Json::from("pchip")),
            ("pid", Json::from(1.0)),
            ("tid", Json::from(l.tid as f64)),
            ("ts", Json::from(l.ts_ns as f64 / 1_000.0)),
        ]));
    }
    obj(vec![("traceEvents", Json::Arr(events)), ("displayTimeUnit", Json::from("ms"))])
}

/// Write the Perfetto/Chrome trace to `path`.
pub fn write_perfetto(path: &Path) -> Result<()> {
    std::fs::write(path, perfetto_json().to_string())
        .with_context(|| format!("writing perfetto trace {}", path.display()))?;
    Ok(())
}

/// Read a JSONL trace back and render the report `pchip report` prints:
/// the summary rollup if the stream carries one, then counter and
/// histogram tables recomputed from the stream.
pub fn report_from_jsonl(path: &Path) -> Result<String> {
    use std::fmt::Write as _;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut out = String::new();
    let mut counters: Vec<(String, Option<usize>, u64)> = Vec::new();
    let mut hists: Vec<(String, Option<usize>, u64, f64, f64)> = Vec::new();
    let mut spans: u64 = 0;
    let mut logs: u64 = 0;
    let mut energy: u64 = 0;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        let die = |v: &Json| -> Option<usize> {
            v.get("die").and_then(|d| d.as_usize().ok())
        };
        match v.get("type").and_then(|t| t.as_str().ok()).unwrap_or("") {
            "summary" => {
                let t = RunTelemetry::from_json(v.req("summary")?)?;
                out.push_str(&t.render());
            }
            "counter" => counters.push((
                v.req("name")?.as_str()?.to_string(),
                die(&v),
                v.req("value")?.as_f64()? as u64,
            )),
            "hist" => hists.push((
                v.req("name")?.as_str()?.to_string(),
                die(&v),
                v.req("count")?.as_f64()? as u64,
                v.req("p50_ns")?.as_f64()? / 1_000.0,
                v.req("p99_ns")?.as_f64()? / 1_000.0,
            )),
            "span_begin" => spans += 1,
            "log" => logs += 1,
            "energy" => energy += 1,
            _ => {}
        }
    }
    if !counters.is_empty() {
        let _ = writeln!(out, "== counters ==");
        for (name, die, v) in &counters {
            let d = die.map(|d| format!("die {d}")).unwrap_or_else(|| "-".into());
            let _ = writeln!(out, "{name:<24} {d:<8} {v}");
        }
    }
    if !hists.is_empty() {
        let _ = writeln!(out, "== histograms ==");
        let _ = writeln!(
            out,
            "{:<24} {:<8} {:>8} {:>12} {:>12}",
            "name", "die", "count", "p50 µs", "p99 µs"
        );
        for (name, die, count, p50, p99) in &hists {
            let d = die.map(|d| format!("die {d}")).unwrap_or_else(|| "-".into());
            let _ = writeln!(out, "{name:<24} {d:<8} {count:>8} {p50:>12.1} {p99:>12.1}");
        }
    }
    let _ = writeln!(out, "== stream ==");
    let _ = writeln!(out, "{spans} spans, {logs} log events, {energy} energy rows");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfetto_document_shape_is_valid_json() {
        // No enablement needed: an empty registry still yields a valid
        // (possibly event-free) trace document.
        let doc = perfetto_json();
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert!(back.req("traceEvents").unwrap().as_arr().is_ok());
    }

    #[test]
    fn jsonl_lines_start_with_meta_and_parse() {
        let lines = jsonl_lines(None, &[]);
        assert!(!lines.is_empty());
        let first = Json::parse(&lines[0]).unwrap();
        assert_eq!(first.req("type").unwrap().as_str().unwrap(), "meta");
        for l in &lines {
            Json::parse(l).unwrap();
        }
    }
}
