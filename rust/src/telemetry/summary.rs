//! Per-run telemetry rollup: the numbers the paper reports, for a run
//! that just happened.
//!
//! [`RunTelemetry::capture`] diffs a registry [`Snapshot`] taken at run
//! start against the registry now, folds in the transport's per-link
//! [`LinkStats`], and produces the headline figures: flips/s per die,
//! barrier-wait and swap-phase latency quantiles, probe/retry counts.
//! It is attached (as an `Option`, `None` when telemetry is off) to
//! `ShardedRun`, `TrainedRun` and `EpochStats`, serialized with the
//! crate's JSON substitute, and printed by `pchip report`.

use crate::metrics::LinkStats;
use crate::util::json::{obj, Json};
use anyhow::Result;

use super::registry::{HistData, Snapshot};

/// Quantile summary of one duration histogram, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    /// Recorded durations.
    pub count: u64,
    /// Median (bucket upper bound — see
    /// [`HistData::quantile_ns`]).
    pub p50_us: f64,
    /// 99th percentile (same caveat).
    pub p99_us: f64,
    /// Exact mean.
    pub mean_us: f64,
}

impl HistSummary {
    fn from_hist(h: &HistData) -> Option<HistSummary> {
        (h.count > 0).then(|| HistSummary {
            count: h.count,
            p50_us: h.quantile_ns(0.50) as f64 / 1_000.0,
            p99_us: h.quantile_ns(0.99) as f64 / 1_000.0,
            mean_us: h.mean_ns() / 1_000.0,
        })
    }

    fn to_json(self) -> Json {
        obj(vec![
            ("count", Json::from(self.count as f64)),
            ("p50_us", Json::from(self.p50_us)),
            ("p99_us", Json::from(self.p99_us)),
            ("mean_us", Json::from(self.mean_us)),
        ])
    }

    fn from_json(v: &Json) -> Result<HistSummary> {
        Ok(HistSummary {
            count: v.req("count")?.as_f64()? as u64,
            p50_us: v.req("p50_us")?.as_f64()?,
            p99_us: v.req("p99_us")?.as_f64()?,
            mean_us: v.req("mean_us")?.as_f64()?,
        })
    }
}

/// Flip throughput attributed to one die (or to unlabeled threads).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DieFlips {
    /// Die label; `None` aggregates threads without one (the serial
    /// CLI path, pool workers).
    pub die: Option<usize>,
    /// Probabilistic flips (spin updates) recorded for this die.
    pub flips: u64,
    /// `flips / wall_s`.
    pub flips_per_sec: f64,
}

/// The per-run telemetry summary (see module docs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RunTelemetry {
    /// Wall-clock duration of the captured window, seconds.
    pub wall_s: f64,
    /// Total flips across all dies.
    pub total_flips: u64,
    /// `total_flips / wall_s`.
    pub flips_per_sec: f64,
    /// Per-die flip throughput.
    pub per_die: Vec<DieFlips>,
    /// Time dies spend blocked at the swap barrier.
    pub barrier_wait: Option<HistSummary>,
    /// Whole swap-phase latency (send + collect + resolve).
    pub swap_phase: Option<HistSummary>,
    /// Per-die sweep-phase latency.
    pub sweep_phase: Option<HistSummary>,
    /// Gradient all-reduce latency (training runs).
    pub all_reduce: Option<HistSummary>,
    /// Probe commands sent to unresponsive dies (elastic runs).
    pub probes: u64,
    /// Recovery retries (rejoin attempts, re-seated work).
    pub retries: u64,
    /// Link delivery totals folded across every transport link.
    pub link: Option<LinkStats>,
}

impl RunTelemetry {
    /// Summarize everything recorded since `before` (a [`Snapshot`]
    /// taken at run start) over `wall_s` seconds, folding per-link
    /// delivery stats in from the transport.
    pub fn capture(before: &Snapshot, wall_s: f64, links: &[LinkStats]) -> RunTelemetry {
        let now = super::registry::snapshot();
        let d = now.diff(before);
        let per_die: Vec<DieFlips> = d
            .counter_by_die("flips")
            .into_iter()
            .map(|(die, flips)| DieFlips {
                die,
                flips,
                flips_per_sec: if wall_s > 0.0 { flips as f64 / wall_s } else { 0.0 },
            })
            .collect();
        let total_flips: u64 = per_die.iter().map(|f| f.flips).sum();
        let link = (!links.is_empty()).then(|| {
            let mut folded = LinkStats::default();
            for l in links {
                folded.merge(l);
            }
            folded
        });
        RunTelemetry {
            wall_s,
            total_flips,
            flips_per_sec: if wall_s > 0.0 { total_flips as f64 / wall_s } else { 0.0 },
            per_die,
            barrier_wait: d.hist_total("barrier_wait").as_ref().and_then(HistSummary::from_hist),
            swap_phase: d.hist_total("swap_phase").as_ref().and_then(HistSummary::from_hist),
            sweep_phase: d.hist_total("sweep_phase").as_ref().and_then(HistSummary::from_hist),
            all_reduce: d.hist_total("all_reduce").as_ref().and_then(HistSummary::from_hist),
            probes: d.counter_total("probe"),
            retries: d.counter_total("retry"),
            link,
        }
    }

    /// Cumulative rollup of everything recorded since the telemetry
    /// epoch (or the last [`crate::telemetry::reset`]) — the variant
    /// stamped onto per-epoch records, where no run-start snapshot
    /// exists. `wall_s` is measured from the telemetry epoch, so the
    /// flips/s figure is a whole-process average.
    pub fn capture_cumulative() -> RunTelemetry {
        RunTelemetry::capture(&Snapshot::default(), super::now_ns() as f64 / 1e9, &[])
    }

    /// Serialize (round-trips through [`RunTelemetry::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("wall_s", Json::from(self.wall_s)),
            ("total_flips", Json::from(self.total_flips as f64)),
            ("flips_per_sec", Json::from(self.flips_per_sec)),
            (
                "per_die",
                Json::Arr(
                    self.per_die
                        .iter()
                        .map(|f| {
                            obj(vec![
                                (
                                    "die",
                                    f.die.map(|d| Json::from(d as f64)).unwrap_or(Json::Null),
                                ),
                                ("flips", Json::from(f.flips as f64)),
                                ("flips_per_sec", Json::from(f.flips_per_sec)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("probes", Json::from(self.probes as f64)),
            ("retries", Json::from(self.retries as f64)),
        ];
        for (key, h) in [
            ("barrier_wait", &self.barrier_wait),
            ("swap_phase", &self.swap_phase),
            ("sweep_phase", &self.sweep_phase),
            ("all_reduce", &self.all_reduce),
        ] {
            if let Some(h) = h {
                pairs.push((key, h.to_json()));
            }
        }
        if let Some(l) = &self.link {
            pairs.push(("link", link_to_json(l)));
        }
        obj(pairs)
    }

    /// Parse back what [`RunTelemetry::to_json`] wrote.
    pub fn from_json(v: &Json) -> Result<RunTelemetry> {
        let hist = |key: &str| -> Result<Option<HistSummary>> {
            v.get(key).map(HistSummary::from_json).transpose()
        };
        let mut per_die = Vec::new();
        if let Some(arr) = v.get("per_die") {
            for f in arr.as_arr()? {
                let die = match f.req("die")? {
                    Json::Null => None,
                    d => Some(d.as_usize()?),
                };
                per_die.push(DieFlips {
                    die,
                    flips: f.req("flips")?.as_f64()? as u64,
                    flips_per_sec: f.req("flips_per_sec")?.as_f64()?,
                });
            }
        }
        Ok(RunTelemetry {
            wall_s: v.req("wall_s")?.as_f64()?,
            total_flips: v.req("total_flips")?.as_f64()? as u64,
            flips_per_sec: v.req("flips_per_sec")?.as_f64()?,
            per_die,
            barrier_wait: hist("barrier_wait")?,
            swap_phase: hist("swap_phase")?,
            sweep_phase: hist("sweep_phase")?,
            all_reduce: hist("all_reduce")?,
            probes: v.req("probes")?.as_f64()? as u64,
            retries: v.req("retries")?.as_f64()? as u64,
            link: v.get("link").map(link_from_json).transpose()?,
        })
    }

    /// Human-readable summary table (what `pchip report` prints).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "== run telemetry ==");
        let _ = writeln!(s, "{:<16} {:.3} s", "wall time", self.wall_s);
        let _ = writeln!(
            s,
            "{:<16} {} ({:.3e} flips/s)",
            "total flips", self.total_flips, self.flips_per_sec
        );
        for f in &self.per_die {
            let label = match f.die {
                Some(d) => format!("die {d}"),
                None => "(no die)".to_string(),
            };
            let _ = writeln!(
                s,
                "{:<16} {} flips ({:.3e} flips/s)",
                label, f.flips, f.flips_per_sec
            );
        }
        for (name, h) in [
            ("sweep_phase", &self.sweep_phase),
            ("swap_phase", &self.swap_phase),
            ("barrier_wait", &self.barrier_wait),
            ("all_reduce", &self.all_reduce),
        ] {
            if let Some(h) = h {
                let _ = writeln!(
                    s,
                    "{:<16} p50 {:>10.1} µs   p99 {:>10.1} µs   mean {:>10.1} µs   (n={})",
                    name, h.p50_us, h.p99_us, h.mean_us, h.count
                );
            }
        }
        if self.probes > 0 || self.retries > 0 {
            let _ =
                writeln!(s, "{:<16} {} probes, {} retries", "recovery", self.probes, self.retries);
        }
        if let Some(l) = &self.link {
            let _ = writeln!(
                s,
                "{:<16} {} sent, {} delivered, {} dropped, {} duplicated, {} suppressed, {} reordered",
                "links",
                l.down.sent + l.up.sent,
                l.delivered(),
                l.dropped(),
                l.down.duplicated + l.up.duplicated,
                l.down.suppressed + l.up.suppressed,
                l.down.reordered + l.up.reordered,
            );
            if l.connects + l.reconnects + l.rejects + l.heartbeats + l.corrupt > 0 {
                let _ = writeln!(
                    s,
                    "{:<16} {} connects, {} reconnects, {} rejects, {} heartbeats, {} corrupt",
                    "sessions", l.connects, l.reconnects, l.rejects, l.heartbeats, l.corrupt,
                );
            }
        }
        s
    }
}

fn lane_to_json(l: &crate::metrics::LaneStats) -> Json {
    obj(vec![
        ("sent", Json::from(l.sent as f64)),
        ("delivered", Json::from(l.delivered as f64)),
        ("dropped", Json::from(l.dropped as f64)),
        ("duplicated", Json::from(l.duplicated as f64)),
        ("suppressed", Json::from(l.suppressed as f64)),
        ("reordered", Json::from(l.reordered as f64)),
    ])
}

fn lane_from_json(v: &Json) -> Result<crate::metrics::LaneStats> {
    Ok(crate::metrics::LaneStats {
        sent: v.req("sent")?.as_f64()? as u64,
        delivered: v.req("delivered")?.as_f64()? as u64,
        dropped: v.req("dropped")?.as_f64()? as u64,
        duplicated: v.req("duplicated")?.as_f64()? as u64,
        suppressed: v.req("suppressed")?.as_f64()? as u64,
        reordered: v.req("reordered")?.as_f64()? as u64,
    })
}

/// Serialize one [`LinkStats`] (used by the summary and the exporters).
pub fn link_to_json(l: &LinkStats) -> Json {
    obj(vec![
        ("down", lane_to_json(&l.down)),
        ("up", lane_to_json(&l.up)),
        ("connects", Json::from(l.connects as f64)),
        ("reconnects", Json::from(l.reconnects as f64)),
        ("rejects", Json::from(l.rejects as f64)),
        ("heartbeats", Json::from(l.heartbeats as f64)),
        ("corrupt", Json::from(l.corrupt as f64)),
    ])
}

/// Parse back what [`link_to_json`] wrote. The lifecycle counters are
/// optional on parse so traces recorded before the socket transport
/// (no `connects`/`reconnects`/... keys) still load.
pub fn link_from_json(v: &Json) -> Result<LinkStats> {
    let opt = |key: &str| -> Result<u64> {
        Ok(match v.get(key) {
            Some(x) => x.as_f64()? as u64,
            None => 0,
        })
    };
    Ok(LinkStats {
        down: lane_from_json(v.req("down")?)?,
        up: lane_from_json(v.req("up")?)?,
        connects: opt("connects")?,
        reconnects: opt("reconnects")?,
        rejects: opt("rejects")?,
        heartbeats: opt("heartbeats")?,
        corrupt: opt("corrupt")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::LaneStats;

    #[test]
    fn json_roundtrip() {
        let t = RunTelemetry {
            wall_s: 1.5,
            total_flips: 440_000,
            flips_per_sec: 440_000.0 / 1.5,
            per_die: vec![
                DieFlips { die: Some(0), flips: 220_000, flips_per_sec: 220_000.0 / 1.5 },
                DieFlips { die: None, flips: 220_000, flips_per_sec: 220_000.0 / 1.5 },
            ],
            barrier_wait: Some(HistSummary { count: 10, p50_us: 4.0, p99_us: 16.0, mean_us: 5.5 }),
            swap_phase: None,
            sweep_phase: None,
            all_reduce: None,
            probes: 2,
            retries: 1,
            link: Some(LinkStats {
                down: LaneStats { sent: 5, delivered: 4, dropped: 1, ..Default::default() },
                up: LaneStats { sent: 3, delivered: 3, ..Default::default() },
                connects: 2,
                reconnects: 1,
                heartbeats: 9,
                ..Default::default()
            }),
        };
        let back = RunTelemetry::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn render_mentions_the_headline_numbers() {
        let t = RunTelemetry {
            wall_s: 2.0,
            total_flips: 1000,
            flips_per_sec: 500.0,
            per_die: vec![DieFlips { die: Some(3), flips: 1000, flips_per_sec: 500.0 }],
            ..Default::default()
        };
        let s = t.render();
        assert!(s.contains("die 3"));
        assert!(s.contains("1000"));
        assert!(s.contains("wall time"));
    }
}
