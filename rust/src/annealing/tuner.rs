//! Offline β-ladder tuning: iterate burn-in → measure → re-space until
//! the ladder converges, auto-sizing K along the way.
//!
//! [`LadderTuning::RoundTripFlux`] re-spaces the ladder *inside* a
//! tempering run; this module is the deliberate, offline version — spend
//! a bounded tuning budget once, get back a [`BetaLadder`] (plus its
//! measured diagnostics) that every subsequent job on the same problem
//! can reuse. The feedback loop:
//!
//! ```text
//!              ┌────────────────────────────────────────────┐
//!              ▼                                            │
//!   measurement burst ──▶ SwapStats ──▶ K sizing            │
//!   (temper, fixed        FluxStats      │ grow: bottleneck │
//!    ladder)                 │           │ shrink: redundant│
//!              ▲             ▼           ▼                  │
//!              │        f(β) profile ──▶ flux re-space ─────┘
//!              │                         (Katzgraber feedback)
//!              └── converged when rungs stop moving (and K is stable)
//! ```
//!
//! Each iteration runs one fixed-ladder tempering burst, then takes
//! exactly one action:
//!
//! * **grow K** while the minimum pairwise swap acceptance sits below
//!   [`TunerParams::acceptance_floor`] — a starving pair means replicas
//!   cannot cross that gap at any spacing of the current K;
//! * **shrink K** when even the bottleneck pair accepts above
//!   [`TunerParams::redundancy_ceiling`] — adjacent rungs are close
//!   enough to be redundant, and a freed chain is a freed replica slot;
//! * otherwise **re-space** at constant K from the measured up-mover
//!   profile ([`BetaLadder::flux_respaced`]), declaring convergence once
//!   the largest rung movement falls below [`TunerParams::tol`].
//!
//! The result maps straight back to silicon: each tuned β is a V_temp
//! DAC code per replica's rung (see `docs/TUNING.md` for the full
//! practitioner guide), and the coordinator serves the whole loop as
//! [`crate::coordinator::JobRequest::TuneLadder`].

use anyhow::{ensure, Result};

use crate::metrics::{FluxStats, SwapStats};
use crate::problems::IsingProblem;
use crate::sampler::Sampler;

use super::schedule::BetaLadder;
use super::tempering::{temper, LadderTuning, TemperingParams};

/// Parameters of one [`tune_ladder`] run.
#[derive(Debug, Clone)]
pub struct TunerParams {
    /// The measurement burst run per iteration: starting ladder, rounds,
    /// sweeps per round and swap seed. `adapt_every`/`tuning` are
    /// ignored — the tuner owns the feedback loop and measures each
    /// candidate ladder *fixed*.
    pub base: TemperingParams,
    /// Maximum burn-in → re-space iterations before giving up (the run
    /// still returns the best ladder found, flagged unconverged).
    pub max_iters: usize,
    /// Convergence threshold: largest per-rung movement of one
    /// re-space, as a fraction of the ladder's ln-β span.
    pub tol: f64,
    /// Grow K while the minimum pairwise acceptance is below this.
    pub acceptance_floor: f64,
    /// Shrink K when the minimum pairwise acceptance exceeds this.
    pub redundancy_ceiling: f64,
    /// Never shrink below this many rungs.
    pub min_k: usize,
    /// Never grow beyond this many rungs (additionally capped by the
    /// sampler's chain count).
    pub max_k: usize,
}

impl Default for TunerParams {
    fn default() -> Self {
        Self {
            base: TemperingParams {
                rounds: 96,
                sweeps_per_round: 4,
                ..TemperingParams::default()
            },
            max_iters: 12,
            tol: 0.02,
            acceptance_floor: 0.2,
            redundancy_ceiling: 0.9,
            min_k: 4,
            max_k: 32,
        }
    }
}

/// What one tuner iteration did, for the diagnostics trail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneAction {
    /// Re-spaced the ladder at constant K from the flux profile.
    Respaced,
    /// Grew the ladder by one rung (acceptance bottleneck starving).
    Grew,
    /// Shrank the ladder by one rung (adjacent rungs redundant).
    Shrank,
}

/// One row of the tuner's diagnostics trail.
#[derive(Debug, Clone)]
pub struct TuneIteration {
    /// Rung count measured this iteration.
    pub k: usize,
    /// Minimum adjacent-pair acceptance of the burst.
    pub min_acceptance: f64,
    /// Attempt-weighted mean acceptance of the burst.
    pub mean_acceptance: f64,
    /// Hot→cold→hot round trips completed during the burst.
    pub round_trips: u64,
    /// Largest rung movement of the re-space, as a fraction of the
    /// ln-β span (0 for grow/shrink iterations).
    pub max_shift: f64,
    /// The action this iteration took.
    pub action: TuneAction,
}

/// What [`tune_ladder`] returns: the tuned ladder plus the final
/// measurement-burst diagnostics, ready to seed production
/// [`TemperingParams`] (or to lower to per-rung V_temp DAC codes).
#[derive(Debug, Clone)]
pub struct TunedLadder {
    /// The converged (or best-so-far) ladder.
    pub ladder: BetaLadder,
    /// Whether the loop converged within the iteration budget.
    pub converged: bool,
    /// Per-iteration diagnostics, in order.
    pub iterations: Vec<TuneIteration>,
    /// Swap counters of the final measurement burst.
    pub swaps: SwapStats,
    /// Flux counters of the final measurement burst.
    pub flux: FluxStats,
    /// The final measured f(β) profile (sanitized, endpoints pinned).
    pub f_profile: Vec<f64>,
    /// Round trips per replica-sweep of the final burst — compare
    /// against a geometric baseline at the same K to see what tuning
    /// bought.
    pub round_trips_per_sweep: f64,
    /// Total per-replica sweeps the whole tuning loop spent.
    pub total_sweeps: u64,
}

impl TunedLadder {
    /// Rung count of the tuned ladder.
    pub fn k(&self) -> usize {
        self.ladder.len()
    }
}

/// Tune a β-ladder for `problem` on `sampler` by round-trip-flux
/// feedback with auto-sized K (see the [module docs](self) for the
/// loop). `beta_scale` converts logical β to the chip knob exactly as
/// in [`temper`]. The sampler keeps its state across bursts (warm
/// start); like `temper`, per-chain βs are left pinned on exit.
///
/// Fails when the starting ladder (or `min_k`) asks for more replicas
/// than the sampler has chains, or on any engine error inside a burst.
pub fn tune_ladder<S: Sampler>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TunerParams,
    beta_scale: f64,
) -> Result<TunedLadder> {
    ensure!(params.max_iters >= 1, "need at least one tuning iteration");
    ensure!(params.min_k >= 2, "min_k must be at least 2, got {}", params.min_k);
    ensure!(
        params.min_k <= params.max_k,
        "min_k {} exceeds max_k {}",
        params.min_k,
        params.max_k
    );
    ensure!(
        params.acceptance_floor < params.redundancy_ceiling,
        "acceptance floor {} must sit below the redundancy ceiling {}",
        params.acceptance_floor,
        params.redundancy_ceiling
    );
    let max_k = params.max_k.min(sampler.batch());
    ensure!(
        params.min_k <= max_k,
        "min_k {} exceeds the sampler's {} chains",
        params.min_k,
        sampler.batch()
    );

    let span = |l: &BetaLadder| l.coldest().ln() - l.hottest().ln();
    let mut ladder = params.base.ladder.clone();
    if ladder.len() > max_k {
        ladder = ladder.resized(max_k);
    } else if ladder.len() < params.min_k {
        ladder = ladder.resized(params.min_k);
    }

    let mut iterations = Vec::with_capacity(params.max_iters);
    let mut total_sweeps = 0u64;
    let mut converged = false;
    let mut last_run = None;
    for iter in 0..params.max_iters {
        let burst = TemperingParams {
            ladder: ladder.clone(),
            adapt_every: 0,
            tuning: LadderTuning::Off,
            seed: params.base.seed.wrapping_add(iter as u64),
            ..params.base.clone()
        };
        let run = temper(sampler, problem, &burst, beta_scale)?;
        total_sweeps += run.total_sweeps;
        let k = ladder.len();
        // bottleneck over pairs that were actually *attempted*: a pair
        // the even/odd parity alternation never reached carries no
        // information and must not read as "fully rejecting" (the same
        // guard the in-run Acceptance path applies) — ∞ when the burst
        // attempted nothing, which disables both resize branches below
        let min_acc = run.swaps.min_attempted_acceptance();
        let mut row = TuneIteration {
            k,
            min_acceptance: if min_acc.is_finite() { min_acc } else { 0.0 },
            mean_acceptance: run.swaps.mean_acceptance(),
            round_trips: run.swaps.round_trips,
            max_shift: 0.0,
            action: TuneAction::Respaced,
        };
        if min_acc < params.acceptance_floor && k < max_k {
            // a starving pair: no re-spacing of K rungs can fix a ladder
            // that is simply too sparse — add a rung and re-measure
            ladder = ladder.resized(k + 1);
            row.action = TuneAction::Grew;
        } else if min_acc.is_finite() && min_acc > params.redundancy_ceiling && k > params.min_k {
            // even the bottleneck accepts almost everything: adjacent
            // rungs are redundant — free a replica slot
            ladder = ladder.resized(k - 1);
            row.action = TuneAction::Shrank;
        } else {
            let next = ladder.flux_respaced(&run.flux.f_profile());
            let shift = ladder
                .betas
                .iter()
                .zip(&next.betas)
                .map(|(a, b)| (a.ln() - b.ln()).abs())
                .fold(0.0f64, f64::max)
                / span(&ladder).max(1e-12);
            row.max_shift = shift;
            if shift < params.tol {
                // converged: keep the ladder that was just *measured* —
                // applying the sub-tol respace would detach the reported
                // diagnostics from the ladder actually returned
                converged = true;
            } else {
                ladder = next;
            }
        }
        iterations.push(row);
        last_run = Some(run);
        if converged {
            break;
        }
    }

    let mut run = last_run.expect("max_iters >= 1 guarantees at least one burst");
    if run.ladder != ladder {
        // the iteration budget ran out right after a resize or an
        // over-tol respace: measure the final ladder once more so the
        // reported diagnostics (swaps, flux, f-profile) describe the
        // ladder actually returned
        let burst = TemperingParams {
            ladder: ladder.clone(),
            adapt_every: 0,
            tuning: LadderTuning::Off,
            seed: params.base.seed.wrapping_add(params.max_iters as u64),
            ..params.base.clone()
        };
        run = temper(sampler, problem, &burst, beta_scale)?;
        total_sweeps += run.total_sweeps;
    }
    Ok(TunedLadder {
        f_profile: run.flux.f_profile(),
        round_trips_per_sweep: run.round_trips_per_sweep(),
        swaps: run.swaps,
        flux: run.flux,
        ladder,
        converged,
        iterations,
        total_sweeps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Personality;
    use crate::chimera::Topology;
    use crate::problems::sk;
    use crate::sampler::SoftwareSampler;

    fn glass_sampler(seed: u64, batch: usize) -> (SoftwareSampler, IsingProblem, f64) {
        let topo = Topology::new();
        let problem = sk::chimera_pm_j(&topo, seed);
        let personality = Personality::ideal(&topo);
        let (j, en, h, scale) = problem.to_codes(&topo).unwrap();
        let mut w = crate::analog::ProgrammedWeights::zeros(topo.edges.len());
        w.j_codes = j;
        w.enables = en;
        w.h_codes = h;
        let folded = personality.fold(&topo, &w);
        let mut s = SoftwareSampler::new(batch, seed);
        s.load(&folded);
        (s, problem, scale)
    }

    fn quick_params(k: usize) -> TunerParams {
        TunerParams {
            base: TemperingParams {
                ladder: BetaLadder::geometric(0.2, 3.0, k),
                sweeps_per_round: 2,
                rounds: 40,
                record_every: 8,
                ..Default::default()
            },
            max_iters: 6,
            tol: 0.08,
            ..Default::default()
        }
    }

    #[test]
    fn tuner_returns_a_valid_ladder_and_trail() {
        let (mut s, problem, scale) = glass_sampler(7, 12);
        let params = quick_params(8);
        let t = tune_ladder(&mut s, &problem, &params, scale).unwrap();
        assert!(t.k() >= params.min_k && t.k() <= 12);
        assert!(t.ladder.betas.windows(2).all(|w| w[1] > w[0]));
        assert!((t.ladder.hottest() - 0.2).abs() < 1e-9, "hot endpoint moved");
        assert!((t.ladder.coldest() - 3.0).abs() < 1e-9, "cold endpoint moved");
        assert!(!t.iterations.is_empty() && t.iterations.len() <= params.max_iters);
        assert_eq!(t.f_profile.len(), t.k());
        assert!(t.total_sweeps >= 80, "one burst is 40 × 2 sweeps");
        assert!(t.round_trips_per_sweep.is_finite());
    }

    #[test]
    fn tuner_grows_a_starving_ladder() {
        let (mut s, problem, scale) = glass_sampler(3, 12);
        // 3 rungs over a wide span: pairwise acceptance will starve
        let mut params = quick_params(3);
        params.base.ladder = BetaLadder::geometric(0.05, 5.0, 3);
        params.acceptance_floor = 0.3;
        params.min_k = 2;
        let t = tune_ladder(&mut s, &problem, &params, scale).unwrap();
        assert!(
            t.iterations.iter().any(|i| i.action == TuneAction::Grew),
            "a 3-rung ladder over β ∈ [0.05, 5] must starve and grow: {:?}",
            t.iterations
        );
        assert!(t.k() > 3);
    }

    #[test]
    fn tuner_shrinks_a_redundant_ladder() {
        let (mut s, problem, scale) = glass_sampler(3, 16);
        // 12 rungs over a sliver of β: every pair accepts nearly always
        let mut params = quick_params(12);
        params.base.ladder = BetaLadder::geometric(1.0, 1.05, 12);
        params.redundancy_ceiling = 0.5;
        params.min_k = 4;
        let t = tune_ladder(&mut s, &problem, &params, scale).unwrap();
        assert!(
            t.iterations.iter().any(|i| i.action == TuneAction::Shrank),
            "a 12-rung ladder over β ∈ [1.0, 1.05] must be redundant: {:?}",
            t.iterations
        );
        assert!(t.k() < 12);
    }

    #[test]
    fn tuner_rejects_bad_budgets() {
        let (mut s, problem, scale) = glass_sampler(1, 8);
        let mut params = quick_params(4);
        params.max_iters = 0;
        assert!(tune_ladder(&mut s, &problem, &params, scale).is_err());
        let mut params = quick_params(4);
        params.min_k = 12; // more than the sampler's 8 chains
        assert!(tune_ladder(&mut s, &problem, &params, scale).is_err());
        let mut params = quick_params(4);
        params.acceptance_floor = 0.95;
        params.redundancy_ceiling = 0.9;
        assert!(tune_ladder(&mut s, &problem, &params, scale).is_err());
    }

    #[test]
    fn tuner_caps_k_at_the_sampler_batch() {
        let (mut s, problem, scale) = glass_sampler(2, 6);
        // starting ladder wants 10 rungs but the die has 6 chains
        let mut params = quick_params(10);
        params.min_k = 2;
        let t = tune_ladder(&mut s, &problem, &params, scale).unwrap();
        assert!(t.k() <= 6, "K must respect the chain budget, got {}", t.k());
    }
}
