//! Replica exchange (parallel tempering) — the multi-replica sampling
//! mode that un-sticks frustrated instances where a single annealed
//! replica stalls.
//!
//! K replicas of the same problem run concurrently as K chains of one
//! batched sampler, each pinned to a rung of a [`BetaLadder`]. Every
//! `sweeps_per_round` sweeps, adjacent-temperature replicas attempt a
//! Metropolis **swap move**: exchange temperatures with probability
//! `min(1, exp(Δβ · ΔE))`. Cold replicas that fall into a local valley
//! are recycled through the hot end where they can escape.
//!
//! The swap criterion uses the *logical* problem energy. On an ideal
//! personality with losslessly-quantized coefficients this is exactly
//! the sampled Hamiltonian (the code↔logical scale cancels in Δβ · ΔE),
//! so swaps preserve detailed balance and every rung samples its exact
//! Boltzmann distribution — the coldest rung's marginals are validated
//! against brute-force enumeration in `rust/tests/tempering_stats.rs`.
//! On a mismatched die the analog path already perturbs the sampled
//! distribution away from any single Hamiltonian, and the swap move is
//! heuristic to the same degree as the sampling itself (as on silicon).
//!
//! The implementation leans on the batched samplers' layout: replicas
//! share one set of CSR coupling arrays and differ only in their state
//! row, noise stream and per-chain β, so a swap is an O(1) exchange of
//! two β entries — **no spin state is copied**. Engines expose this via
//! [`Sampler::set_betas`]; the pure-rust [`SoftwareSampler`] supports it
//! natively, while the AOT/XLA artifact (scalar-β signature) and the
//! cycle-level chip (one V_temp rail) report unsupported.
//!
//! [`SoftwareSampler`]: crate::sampler::SoftwareSampler

use anyhow::{ensure, Result};

use crate::metrics::{EnergyTrace, SwapStats};
use crate::problems::IsingProblem;
use crate::rng::HostRng;
use crate::sampler::Sampler;

use super::schedule::BetaLadder;

/// Parameters of one tempering run.
#[derive(Debug, Clone)]
pub struct TemperingParams {
    /// The β-ladder; one replica per rung. `ladder.len()` must not
    /// exceed the sampler's batch.
    pub ladder: BetaLadder,
    /// Sweeps between swap phases (the "S" knob: small S mixes
    /// temperatures faster, large S amortizes the energy evaluation).
    pub sweeps_per_round: usize,
    /// Number of sweep+swap rounds.
    pub rounds: usize,
    /// Re-space the ladder from measured acceptance every this many
    /// rounds (0 = fixed ladder). Endpoints stay pinned.
    pub adapt_every: usize,
    /// Record the energy trace every `record_every` rounds.
    pub record_every: usize,
    /// Seed of the swap-decision RNG (replica dynamics themselves draw
    /// from the sampler's own noise streams).
    pub seed: u64,
}

impl Default for TemperingParams {
    fn default() -> Self {
        Self {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 4,
            rounds: 128,
            adapt_every: 0,
            record_every: 4,
            seed: 0x7E6F,
        }
    }
}

impl TemperingParams {
    /// Per-replica sweeps of the whole run.
    pub fn total_sweeps(&self) -> usize {
        self.rounds * self.sweeps_per_round
    }

    /// Simulated chip time of one run in ns. Replicas run concurrently
    /// on-die (one chain each), so wall time is sweeps × sample time —
    /// directly comparable with an anneal's restart time in
    /// [`crate::annealing::tts99`].
    pub fn chip_time_ns(&self) -> f64 {
        self.total_sweeps() as f64 * crate::chip::SAMPLE_TIME_NS
    }
}

/// What a tempering run returns.
#[derive(Debug, Clone)]
pub struct TemperingRun {
    /// (sweep, coldest-rung β, mean replica energy, min replica energy)
    /// rows — same shape as an anneal trace so the Fig 9 tooling can
    /// overlay the two modes.
    pub trace: EnergyTrace,
    /// Best energy seen by any replica at any round.
    pub best_energy: f64,
    pub best_state: Vec<i8>,
    /// Swap acceptance / round-trip diagnostics.
    pub swaps: SwapStats,
    /// The final ladder (differs from the input when `adapt_every > 0`).
    pub ladder: BetaLadder,
    /// Per-replica sweeps performed.
    pub total_sweeps: u64,
}

/// Run replica exchange on a batched sampler. `beta_scale` converts
/// logical β to the chip knob exactly as in [`super::anneal`]; the swap
/// criterion uses logical β × logical energy, which equals chip-β ×
/// chip-energy because the scale cancels.
///
/// The sampler's first `ladder.len()` chains are the replicas; any extra
/// chains run at the hottest β as free scouts (they join the best-energy
/// search but not the swap dynamics).
pub fn temper<S: Sampler>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
) -> Result<TemperingRun> {
    temper_observed(sampler, problem, params, beta_scale, |_, _, _| {})
}

/// [`temper`] with a per-round observer `observe(round, states,
/// chain_at_rung)` called after each sweep phase — the hook the
/// statistical validation tests use to accumulate per-rung marginals.
pub fn temper_observed<S, F>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
    mut observe: F,
) -> Result<TemperingRun>
where
    S: Sampler,
    F: FnMut(usize, &[Vec<i8>], &[usize]),
{
    let k = params.ladder.len();
    let batch = sampler.batch();
    ensure!(k >= 2, "tempering needs at least two rungs, got {k}");
    ensure!(
        k <= batch,
        "ladder has {k} rungs but the sampler only has {batch} chains"
    );
    ensure!(params.sweeps_per_round > 0, "sweeps_per_round must be positive");
    ensure!(params.record_every > 0, "record_every must be positive");

    let mut ladder = params.ladder.clone();
    // chain_at_rung[r] = chain currently holding rung r's temperature.
    let mut chain_at_rung: Vec<usize> = (0..k).collect();
    // Round-trip labels: which ladder end each chain last visited.
    const END_NONE: u8 = 0;
    const END_HOT: u8 = 1;
    const END_COLD: u8 = 2;
    let mut last_end = vec![END_NONE; batch];

    let mut swaps = SwapStats::new(k);
    // Windowed counters for ladder adaptation (reset after each adapt).
    let mut window = SwapStats::new(k);
    let mut rng = HostRng::new(params.seed ^ 0x7E3A_94C1);
    let mut trace = EnergyTrace::default();
    let mut best = (f64::INFINITY, Vec::new());
    let mut sweeps_done = 0u64;

    let mut chain_betas = vec![0.0f32; batch];
    for round in 0..params.rounds {
        // 1. pin each chain to its rung's chip-β; extras scout hot
        for b in chain_betas.iter_mut() {
            *b = (ladder.hottest() * beta_scale) as f32;
        }
        for (r, &c) in chain_at_rung.iter().enumerate() {
            chain_betas[c] = (ladder.betas[r] * beta_scale) as f32;
        }
        sampler.set_betas(&chain_betas)?;

        // 2. sweep all replicas
        sampler.sweeps(params.sweeps_per_round)?;
        sweeps_done += params.sweeps_per_round as u64;

        // 3. energies (logical), best-state tracking (over every chain,
        //    scouts included), observer
        let states = sampler.states();
        let energies: Vec<f64> = states.iter().map(|s| problem.energy(s)).collect();
        for (e, s) in energies.iter().zip(&states) {
            if *e < best.0 {
                best = (*e, s.clone());
            }
        }
        observe(round, &states, &chain_at_rung);

        // 4. swap phase: alternate even/odd pairings so every adjacent
        //    pair is attempted every other round
        for r in ((round % 2)..k - 1).step_by(2) {
            let (ca, cb) = (chain_at_rung[r], chain_at_rung[r + 1]);
            let d_beta = ladder.betas[r + 1] - ladder.betas[r];
            let d_energy = energies[cb] - energies[ca];
            // π swap ratio = exp((β_cold − β_hot)(E_cold − E_hot))
            let log_a = d_beta * d_energy;
            let accept = log_a >= 0.0 || rng.uniform() < log_a.exp();
            swaps.record(r, accept);
            window.record(r, accept);
            if accept {
                chain_at_rung.swap(r, r + 1);
            }
        }

        // 5. round-trip accounting at the ladder ends
        let hot_chain = chain_at_rung[0];
        let cold_chain = chain_at_rung[k - 1];
        if last_end[hot_chain] == END_COLD {
            swaps.round_trips += 1;
        }
        last_end[hot_chain] = END_HOT;
        last_end[cold_chain] = END_COLD;

        // 6. trace (over the K replicas only — hot scouts would skew the
        //    mean against an anneal trace) + optional ladder adaptation
        if round % params.record_every == 0 || round == params.rounds - 1 {
            let replica_e = chain_at_rung.iter().map(|&c| energies[c]);
            let mean = replica_e.clone().sum::<f64>() / k as f64;
            let min = replica_e.fold(f64::INFINITY, f64::min);
            trace.push(sweeps_done, ladder.coldest(), mean, min);
        }
        if params.adapt_every > 0 && round > 0 && round % params.adapt_every == 0 {
            // Pairs never attempted in this window (short windows only
            // see one parity) carry no information: fill them with the
            // window's mean acceptance instead of letting a 0 read as
            // "fully rejecting" and wrench the ladder toward them.
            let mut rates = window.acceptance_rates();
            let measured: Vec<f64> = window
                .attempts
                .iter()
                .zip(&rates)
                .filter(|(&a, _)| a > 0)
                .map(|(_, &r)| r)
                .collect();
            if !measured.is_empty() {
                let fill = measured.iter().sum::<f64>() / measured.len() as f64;
                for (a, r) in window.attempts.iter().zip(rates.iter_mut()) {
                    if *a == 0 {
                        *r = fill;
                    }
                }
                ladder = ladder.adapted(&rates);
            }
            window = SwapStats::new(k);
        }
    }

    Ok(TemperingRun {
        trace,
        best_energy: best.0,
        best_state: best.1,
        swaps,
        ladder,
        total_sweeps: sweeps_done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Personality;
    use crate::chimera::Topology;
    use crate::problems::sk;
    use crate::sampler::SoftwareSampler;

    fn glass_sampler(seed: u64, batch: usize) -> (SoftwareSampler, IsingProblem, f64) {
        let topo = Topology::new();
        let problem = sk::chimera_pm_j(&topo, seed);
        let personality = Personality::ideal(&topo);
        let (j, en, h, scale) = problem.to_codes(&topo).unwrap();
        let mut w = crate::analog::ProgrammedWeights::zeros(topo.edges.len());
        w.j_codes = j;
        w.enables = en;
        w.h_codes = h;
        let folded = personality.fold(&topo, &w);
        let mut s = SoftwareSampler::new(batch, seed);
        s.load(&folded);
        (s, problem, scale)
    }

    #[test]
    fn tempering_lowers_energy_on_a_glass() {
        let (mut s, problem, scale) = glass_sampler(7, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 48,
            record_every: 4,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        let first_mean = run.trace.rows.first().unwrap().2;
        assert!(
            run.best_energy < first_mean - 50.0,
            "tempering should drop energy substantially: {first_mean} → {}",
            run.best_energy
        );
        assert_eq!(run.best_state.len(), crate::N_SPINS);
        assert_eq!(run.total_sweeps, 96);
    }

    #[test]
    fn swaps_are_attempted_and_some_accepted() {
        let (mut s, problem, scale) = glass_sampler(3, 16);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.3, 2.0, 16),
            sweeps_per_round: 2,
            rounds: 60,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        let attempts: u64 = run.swaps.attempts.iter().sum();
        // 15 pairs, alternating parity → ~450 attempts over 60 rounds
        assert!(attempts > 300, "attempts {attempts}");
        assert!(run.swaps.mean_acceptance() > 0.0, "no swap ever accepted");
    }

    #[test]
    fn ladder_larger_than_batch_is_rejected() {
        let (mut s, problem, scale) = glass_sampler(1, 4);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            ..Default::default()
        };
        assert!(temper(&mut s, &problem, &params, scale).is_err());
    }

    #[test]
    fn adaptation_keeps_endpoints_and_order() {
        let (mut s, problem, scale) = glass_sampler(5, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 40,
            adapt_every: 10,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        assert!((run.ladder.hottest() - 0.1).abs() < 1e-12);
        assert!((run.ladder.coldest() - 4.0).abs() < 1e-12);
        assert!(run.ladder.betas.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn observer_sees_every_round() {
        let (mut s, problem, scale) = glass_sampler(2, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.2, 2.0, 4),
            sweeps_per_round: 1,
            rounds: 12,
            ..Default::default()
        };
        let mut seen = 0usize;
        temper_observed(&mut s, &problem, &params, scale, |round, states, map| {
            assert_eq!(round, seen);
            assert_eq!(states.len(), 8);
            assert_eq!(map.len(), 4);
            seen += 1;
        })
        .unwrap();
        assert_eq!(seen, 12);
    }
}
