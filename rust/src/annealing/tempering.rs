//! Replica exchange (parallel tempering) — the multi-replica sampling
//! mode that un-sticks frustrated instances where a single annealed
//! replica stalls.
//!
//! K replicas of the same problem run concurrently as K chains of one
//! batched sampler, each pinned to a rung of a [`BetaLadder`]. Every
//! `sweeps_per_round` sweeps, adjacent-temperature replicas attempt a
//! Metropolis **swap move**: exchange temperatures with probability
//! `min(1, exp(Δβ · ΔE))`. Cold replicas that fall into a local valley
//! are recycled through the hot end where they can escape.
//!
//! The swap criterion uses the *logical* problem energy. On an ideal
//! personality with losslessly-quantized coefficients this is exactly
//! the sampled Hamiltonian (the code↔logical scale cancels in Δβ · ΔE),
//! so swaps preserve detailed balance and every rung samples its exact
//! Boltzmann distribution — the coldest rung's marginals are validated
//! against brute-force enumeration in `rust/tests/tempering_stats.rs`.
//! On a mismatched die the analog path already perturbs the sampled
//! distribution away from any single Hamiltonian, and the swap move is
//! heuristic to the same degree as the sampling itself (as on silicon).
//!
//! The implementation leans on the batched samplers' layout: replicas
//! share one set of CSR coupling arrays and differ only in their state
//! row, noise stream and per-chain β, so a swap is an O(1) exchange of
//! two β entries — **no spin state is copied**. Engines expose this via
//! [`Sampler::set_betas`]; the pure-rust [`SoftwareSampler`] supports it
//! natively, while the AOT/XLA artifact (scalar-β signature) and the
//! cycle-level chip (one V_temp rail) report unsupported.
//!
//! Sweep work between swap phases rides the engines' own scheduling:
//! batched engines fan their chains over the persistent core-pinned
//! sweep-worker pool ([`crate::sampler::workers`]) once a round is
//! large enough to amortize the hand-off, so tempering no longer pays
//! thread spawn/join per round (the old per-`sweeps()` spawn). Chain
//! streams are seed-deterministic, so pooled and serial rounds are
//! bit-identical.
//!
//! Energy readback is incremental where the engine allows it: the run
//! installs a [`crate::problems::EnergyLedger`]
//! ([`Sampler::track_energies`]) so each swap phase reads per-chain
//! energies in O(chains) off exact per-flip ΔE deltas accumulated
//! during the sweep, instead of an O(chains·N·deg) rescan. On
//! losslessly-quantized problems (±1 coefficients — every validation
//! instance) the ledger readback equals [`IsingProblem::energy`] bit
//! for bit; on a lossy lowering it reads the *code-domain* Hamiltonian,
//! which is what the die actually samples.
//!
//! Two schedules drive the same core: the serial [`temper`] (swap phase
//! strictly between sweeps) and the pipelined [`temper_pipelined`] /
//! [`PipelinedCore`] (swap phases resolved one phase behind the sweeps
//! they feed, so a distributed run never stalls its update pipeline —
//! see the `--pipeline` flag and [`crate::coordinator`]).
//!
//! [`SoftwareSampler`]: crate::sampler::SoftwareSampler

use anyhow::{ensure, Result};

use crate::metrics::{EnergyTrace, FluxStats, ReplicaDirection, SwapStats};
use crate::problems::{EnergyLedger, IsingProblem};
use crate::rng::HostRng;
use crate::sampler::Sampler;

use super::schedule::BetaLadder;

/// The per-run energy readback: an [`EnergyLedger`] installed on the
/// engine where it supports incremental tracking, and kept coordinator-
/// side for the rescan fallback otherwise, so **every** engine scores
/// swaps against the same code-domain Hamiltonian — the one the die
/// actually samples. On losslessly-quantized problems (±1 coefficients,
/// every suite instance) that readback is bit-equal to
/// [`IsingProblem::energy`]; only when even building the ledger fails
/// does the readback fall back to the logical rescan.
pub(crate) struct EnergyReadback {
    ledger: Option<EnergyLedger>,
    tracked: bool,
}

impl EnergyReadback {
    /// Build the ledger for `problem` and try to install it on the
    /// engine ([`Sampler::track_energies`]). Engines without a flip
    /// stream (the AOT artifact) decline; the rescan fallback then
    /// reads the same ledger so the energies agree bit for bit across
    /// engines.
    pub(crate) fn install<S: Sampler + ?Sized>(sampler: &mut S, problem: &IsingProblem) -> Self {
        match EnergyLedger::for_problem(problem) {
            Ok(ledger) => {
                let tracked = sampler.track_energies(&ledger).is_ok();
                Self { ledger: Some(ledger), tracked }
            }
            Err(_) => Self { ledger: None, tracked: false },
        }
    }

    /// Per-chain energies after a sweep phase: O(chains) off the
    /// tracked ledger when live, else the O(chains·N·deg) rescan
    /// (borrowing each state via [`Sampler::for_each_state`] — no
    /// clone).
    pub(crate) fn read<S: Sampler + ?Sized>(
        &self,
        sampler: &mut S,
        problem: &IsingProblem,
    ) -> Vec<f64> {
        if self.tracked {
            if let Ok(e) = sampler.energies() {
                return e;
            }
        }
        let mut out = Vec::with_capacity(sampler.batch());
        match &self.ledger {
            Some(l) => sampler.for_each_state(&mut |_, st| out.push(l.logical(l.full_code(st)))),
            None => sampler.for_each_state(&mut |_, st| out.push(problem.energy(st))),
        }
        out
    }
}

/// Which feedback signal drives in-run ladder re-spacing (applied every
/// [`TemperingParams::adapt_every`] rounds; irrelevant when that is 0).
///
/// For the offline tuning loop that also auto-sizes K, see
/// [`crate::annealing::tune_ladder`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LadderTuning {
    /// Never re-space, even when `adapt_every > 0`.
    Off,
    /// Equalize measured adjacent-pair swap acceptance
    /// ([`BetaLadder::adapted`]) — cheap, converges fast, but blind to
    /// replicas ping-ponging between two rungs. The historical default.
    #[default]
    Acceptance,
    /// Equalize round-trip flux from the measured up-mover profile
    /// ([`BetaLadder::flux_respaced`], Katzgraber-style feedback) —
    /// optimizes what actually matters (hot→cold→hot round trips) at
    /// the cost of needing enough rounds per window for replicas to
    /// traverse the ladder and earn direction labels.
    RoundTripFlux,
}

/// Parameters of one tempering run.
#[derive(Debug, Clone)]
pub struct TemperingParams {
    /// The β-ladder; one replica per rung. `ladder.len()` must not
    /// exceed the sampler's batch.
    pub ladder: BetaLadder,
    /// Sweeps between swap phases (the "S" knob: small S mixes
    /// temperatures faster, large S amortizes the energy evaluation).
    pub sweeps_per_round: usize,
    /// Number of sweep+swap rounds.
    pub rounds: usize,
    /// Re-space the ladder every this many rounds (0 = fixed ladder).
    /// Endpoints stay pinned; [`TemperingParams::tuning`] picks the
    /// feedback signal.
    pub adapt_every: usize,
    /// Which feedback re-spaces the ladder when `adapt_every > 0`.
    pub tuning: LadderTuning,
    /// Record the energy trace every `record_every` rounds.
    pub record_every: usize,
    /// Seed of the swap-decision RNG (replica dynamics themselves draw
    /// from the sampler's own noise streams).
    pub seed: u64,
}

impl Default for TemperingParams {
    fn default() -> Self {
        Self {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 4,
            rounds: 128,
            adapt_every: 0,
            tuning: LadderTuning::Acceptance,
            record_every: 4,
            seed: 0x7E6F,
        }
    }
}

impl TemperingParams {
    /// Per-replica sweeps of the whole run.
    pub fn total_sweeps(&self) -> usize {
        self.rounds * self.sweeps_per_round
    }

    /// Simulated chip time of one run in ns. Replicas run concurrently
    /// on-die (one chain each), so wall time is sweeps × sample time —
    /// directly comparable with an anneal's restart time in
    /// [`crate::annealing::tts99`].
    pub fn chip_time_ns(&self) -> f64 {
        self.total_sweeps() as f64 * crate::chip::SAMPLE_TIME_NS
    }
}

/// What a tempering run returns.
#[derive(Debug, Clone)]
pub struct TemperingRun {
    /// (sweep, coldest-rung β, mean replica energy, min replica energy)
    /// rows — same shape as an anneal trace so the Fig 9 tooling can
    /// overlay the two modes.
    pub trace: EnergyTrace,
    /// Best energy seen by any replica at any round.
    pub best_energy: f64,
    /// The spin state that reached [`TemperingRun::best_energy`].
    pub best_state: Vec<i8>,
    /// Swap acceptance / round-trip diagnostics.
    pub swaps: SwapStats,
    /// Per-rung up/down-mover occupancy — the measured f(β) profile
    /// that [`BetaLadder::flux_respaced`] consumes.
    pub flux: FluxStats,
    /// The final ladder (differs from the input when `adapt_every > 0`).
    pub ladder: BetaLadder,
    /// Per-replica sweeps performed.
    pub total_sweeps: u64,
}

impl TemperingRun {
    /// Completed hot→cold→hot round trips per replica-sweep — the
    /// ladder-mixing figure [`crate::annealing::tune_ladder`] optimizes
    /// (swap acceptance can look healthy while replicas ping-pong; this
    /// cannot).
    pub fn round_trips_per_sweep(&self) -> f64 {
        if self.total_sweeps == 0 {
            0.0
        } else {
            self.swaps.round_trips as f64 / self.total_sweeps as f64
        }
    }
}

/// The resumable tempering state machine: everything [`temper`] tracks
/// *between* sweep phases — the rung↔chain map, swap RNG, diagnostics,
/// trace, best-state and the (possibly adapting) ladder.
///
/// One round of replica exchange splits into two halves:
///
/// 1. a **sweep phase** — pin per-chain βs ([`Self::chain_betas`]), run
///    `sweeps_per_round` sweeps, read back states and energies. This
///    half touches only the sampler and can run anywhere (one die, or
///    one *shard* of a die array).
/// 2. a **swap phase** — [`Self::finish_round`]: Metropolis swap moves
///    over adjacent rung pairs, round-trip bookkeeping, trace recording
///    and optional ladder adaptation. This half touches only the core's
///    own state and is where a distributed run must synchronize.
///
/// [`temper`] drives the core against a single sampler;
/// [`crate::coordinator::run_sharded_tempering`] drives the same core
/// with the sweep phase fanned out across dies, pausing each shard at
/// the swap barrier. Because every β-comparison, RNG draw and counter
/// update lives here, a 1-shard sharded run is **bit-identical** to
/// [`temper`] (proven by `rust/tests/sharded_equivalence.rs`).
pub struct TemperingCore {
    params: TemperingParams,
    ladder: BetaLadder,
    /// chain_at_rung[r] = chain currently holding rung r's temperature.
    chain_at_rung: Vec<usize>,
    /// Round-trip labels: which ladder end each chain last visited —
    /// doubles as the replica's up/down direction label, and travels
    /// with the chain (the spin state), not the rung, so a boundary
    /// swap in the sharded engine carries it along with the
    /// β-assignment for free.
    last_end: Vec<u8>,
    swaps: SwapStats,
    flux: FluxStats,
    /// Windowed counters for ladder adaptation (reset after each adapt).
    window: SwapStats,
    window_flux: FluxStats,
    rng: HostRng,
    trace: EnergyTrace,
    best: (f64, Vec<i8>),
    sweeps_done: u64,
    batch: usize,
}

const END_NONE: u8 = 0;
const END_HOT: u8 = 1;
const END_COLD: u8 = 2;

impl TemperingCore {
    /// Core over `batch` chains with the identity rung→chain assignment
    /// (rung r starts on chain r; extra chains scout at the hottest β).
    pub fn new(params: &TemperingParams, batch: usize) -> Result<Self> {
        let k = params.ladder.len();
        Self::with_assignment(params, batch, (0..k).collect())
    }

    /// Core with an explicit initial rung→chain assignment — the sharded
    /// coordinator maps rung ranges onto per-die chain blocks, so rung r
    /// of shard s starts on chain `offset(s) + (r − range(s).start)`.
    pub fn with_assignment(
        params: &TemperingParams,
        batch: usize,
        chain_at_rung: Vec<usize>,
    ) -> Result<Self> {
        let k = params.ladder.len();
        ensure!(k >= 2, "tempering needs at least two rungs, got {k}");
        ensure!(
            k <= batch,
            "ladder has {k} rungs but the sampler only has {batch} chains"
        );
        ensure!(params.sweeps_per_round > 0, "sweeps_per_round must be positive");
        ensure!(params.record_every > 0, "record_every must be positive");
        ensure!(
            chain_at_rung.len() == k,
            "assignment covers {} rungs but the ladder has {k}",
            chain_at_rung.len()
        );
        let mut seen = vec![false; batch];
        for &c in &chain_at_rung {
            ensure!(c < batch, "rung assigned to chain {c} but there are only {batch} chains");
            ensure!(!seen[c], "chain {c} assigned to two rungs");
            seen[c] = true;
        }
        Ok(Self {
            params: params.clone(),
            ladder: params.ladder.clone(),
            chain_at_rung,
            last_end: vec![END_NONE; batch],
            swaps: SwapStats::new(k),
            flux: FluxStats::new(k),
            window: SwapStats::new(k),
            window_flux: FluxStats::new(k),
            rng: HostRng::new(params.seed ^ 0x7E3A_94C1),
            trace: EnergyTrace::default(),
            best: (f64::INFINITY, Vec::new()),
            sweeps_done: 0,
            batch,
        })
    }

    /// Number of chains the core accounts for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Rounds the run is configured for.
    pub fn rounds(&self) -> usize {
        self.params.rounds
    }

    /// Sweeps in each sweep phase.
    pub fn sweeps_per_round(&self) -> usize {
        self.params.sweeps_per_round
    }

    /// The current rung→chain map (rung 0 = hottest).
    pub fn chain_at_rung(&self) -> &[usize] {
        &self.chain_at_rung
    }

    /// Chip-β for every chain this round: each replica chain pinned to
    /// its rung's β × `beta_scale`, every non-replica chain scouting at
    /// the hottest β.
    pub fn chain_betas(&self, beta_scale: f64) -> Vec<f32> {
        let mut betas = vec![(self.ladder.hottest() * beta_scale) as f32; self.batch];
        for (r, &c) in self.chain_at_rung.iter().enumerate() {
            betas[c] = (self.ladder.betas[r] * beta_scale) as f32;
        }
        betas
    }

    /// Complete round `round` from its sweep-phase output: best-state
    /// tracking over every chain (scouts included), the Metropolis swap
    /// phase, round-trip accounting, trace recording and (when
    /// `adapt_every > 0`) ladder adaptation. `energies`/`states` are
    /// indexed by chain and must cover the full batch.
    pub fn finish_round(&mut self, round: usize, energies: &[f64], states: &[Vec<i8>]) {
        assert_eq!(energies.len(), self.batch, "need one energy per chain");
        assert_eq!(states.len(), self.batch, "need one state per chain");
        let k = self.ladder.len();
        self.sweeps_done += self.params.sweeps_per_round as u64;

        for (e, s) in energies.iter().zip(states) {
            if *e < self.best.0 {
                self.best = (*e, s.clone());
            }
        }

        // swap phase: alternate even/odd pairings so every adjacent
        // pair is attempted every other round
        for r in ((round % 2)..k - 1).step_by(2) {
            let (ca, cb) = (self.chain_at_rung[r], self.chain_at_rung[r + 1]);
            let d_beta = self.ladder.betas[r + 1] - self.ladder.betas[r];
            let d_energy = energies[cb] - energies[ca];
            // π swap ratio = exp((β_cold − β_hot)(E_cold − E_hot))
            let log_a = d_beta * d_energy;
            let accept = log_a >= 0.0 || self.rng.uniform() < log_a.exp();
            self.swaps.record(r, accept);
            self.window.record(r, accept);
            if accept {
                self.chain_at_rung.swap(r, r + 1);
            }
        }

        // round-trip accounting at the ladder ends
        let hot_chain = self.chain_at_rung[0];
        let cold_chain = self.chain_at_rung[k - 1];
        if self.last_end[hot_chain] == END_COLD {
            self.swaps.round_trips += 1;
        }
        self.last_end[hot_chain] = END_HOT;
        self.last_end[cold_chain] = END_COLD;

        // flux tally: each rung's occupant contributes one visit under
        // its direction label (END_HOT = up-mover heading cold-ward,
        // END_COLD = down-mover, END_NONE = not yet labeled). Pure
        // counter updates — no RNG draw — so the swap decisions and the
        // sharded engine's bit-exactness are untouched.
        for (r, &c) in self.chain_at_rung.iter().enumerate() {
            let dir = match self.last_end[c] {
                END_HOT => ReplicaDirection::Up,
                END_COLD => ReplicaDirection::Down,
                _ => ReplicaDirection::Unlabeled,
            };
            self.flux.record(r, dir);
            self.window_flux.record(r, dir);
        }

        // trace (over the K replicas only — hot scouts would skew the
        // mean against an anneal trace) + optional ladder adaptation
        if round % self.params.record_every == 0 || round == self.params.rounds - 1 {
            let replica_e = self.chain_at_rung.iter().map(|&c| energies[c]);
            let mean = replica_e.clone().sum::<f64>() / k as f64;
            let min = replica_e.fold(f64::INFINITY, f64::min);
            self.trace.push(self.sweeps_done, self.ladder.coldest(), mean, min);
        }
        if self.params.adapt_every > 0 && round > 0 && round % self.params.adapt_every == 0 {
            match self.params.tuning {
                LadderTuning::Off => {}
                LadderTuning::Acceptance => {
                    // Pairs never attempted in this window (short windows
                    // only see one parity) carry no information: fill them
                    // with the window's mean acceptance instead of letting
                    // a 0 read as "fully rejecting" and wrench the ladder
                    // toward them.
                    let mut rates = self.window.acceptance_rates();
                    let measured: Vec<f64> = self
                        .window
                        .attempts
                        .iter()
                        .zip(&rates)
                        .filter(|(&a, _)| a > 0)
                        .map(|(_, &r)| r)
                        .collect();
                    if !measured.is_empty() {
                        let fill = measured.iter().sum::<f64>() / measured.len() as f64;
                        for (a, r) in self.window.attempts.iter().zip(rates.iter_mut()) {
                            if *a == 0 {
                                *r = fill;
                            }
                        }
                        self.ladder = self.ladder.adapted(&rates);
                    }
                }
                LadderTuning::RoundTripFlux => {
                    // unmeasured rungs interpolate inside f_profile, so a
                    // short window cannot wrench the ladder either
                    self.ladder = self.ladder.flux_respaced(&self.window_flux.f_profile());
                }
            }
            self.window = SwapStats::new(k);
            self.window_flux = FluxStats::new(k);
        }
    }

    /// The cumulative flux counters collected so far.
    pub fn flux(&self) -> &FluxStats {
        &self.flux
    }

    /// The current ladder (moves from the input when `adapt_every > 0`)
    /// — long-lived embedders like the training service's tempered
    /// negative phase read it for diagnostics between rounds.
    pub fn ladder(&self) -> &BetaLadder {
        &self.ladder
    }

    /// Finalize into a [`TemperingRun`].
    pub fn into_run(self) -> TemperingRun {
        TemperingRun {
            trace: self.trace,
            best_energy: self.best.0,
            best_state: self.best.1,
            swaps: self.swaps,
            flux: self.flux,
            ladder: self.ladder,
            total_sweeps: self.sweeps_done,
        }
    }
}

/// The double-buffered half of the pipelined replica-exchange engine:
/// a [`TemperingCore`] split into a **launch** side (hand out the next
/// sweep phase's β slice) and a **score** side (swap phase over a
/// *previous* phase's readback), with at most two phases in flight.
///
/// The serial engine alternates `sweep(t) → swap(t) → sweep(t+1)`, so
/// every sweep stalls behind the energy readback and swap resolution of
/// the phase before it. The pipelined schedule overlaps them:
///
/// ```text
///   launch:  phase 0   phase 1   phase 2   phase 3      (workers sweep)
///   score:             phase 0   phase 1   phase 2      (coordinator)
/// ```
///
/// Phase *t+1* therefore sweeps under the rung→chain assignment left by
/// the swap phase of *t−1* — the **1-phase lag**. Swap decisions are
/// resolved one phase behind the sweeps they feed: a replica that wins
/// a β-exchange at phase *t* starts sweeping at its new temperature at
/// phase *t+2* instead of *t+1*. Everything else — the Metropolis
/// criterion, RNG stream, round-trip/flux accounting, trace cadence,
/// ladder adaptation — is the unmodified [`TemperingCore`], consumed in
/// strict phase order, so the schedule is exactly as deterministic and
/// seed-reproducible as the serial one (pinned by
/// `rust/tests/pipelined_equivalence.rs`: the overlapped sharded
/// execution is bit-identical to [`temper_pipelined`], the serial
/// reference of the same lagged schedule).
///
/// The lag trades one phase of temperature-mixing latency for never
/// stalling the update pipeline — the asynchronous scheduling PASS
/// (Patel et al., 2024) shows unlocks throughput in p-bit processors.
/// It leaves each rung's *sweep* dynamics at most one neighbouring rung
/// away from its assignment, and the swap criterion itself still
/// compares exact energies under exact Δβ, so the stationary behaviour
/// matches the serial engine within statistical error (the suite
/// checks cold-rung marginals against exact Boltzmann).
pub struct PipelinedCore {
    core: TemperingCore,
    launched: usize,
    scored: usize,
}

impl PipelinedCore {
    /// Pipelined core over `batch` chains with the identity rung→chain
    /// assignment (mirrors [`TemperingCore::new`]).
    pub fn new(params: &TemperingParams, batch: usize) -> Result<Self> {
        Ok(Self { core: TemperingCore::new(params, batch)?, launched: 0, scored: 0 })
    }

    /// Pipelined core with an explicit initial assignment (mirrors
    /// [`TemperingCore::with_assignment`] — the sharded coordinator's
    /// entry point).
    pub fn with_assignment(
        params: &TemperingParams,
        batch: usize,
        chain_at_rung: Vec<usize>,
    ) -> Result<Self> {
        Ok(Self {
            core: TemperingCore::with_assignment(params, batch, chain_at_rung)?,
            launched: 0,
            scored: 0,
        })
    }

    /// Rounds the run is configured for.
    pub fn rounds(&self) -> usize {
        self.core.rounds()
    }

    /// Sweeps in each sweep phase.
    pub fn sweeps_per_round(&self) -> usize {
        self.core.sweeps_per_round()
    }

    /// The current rung→chain map (reflects swaps of every *scored*
    /// phase).
    pub fn chain_at_rung(&self) -> &[usize] {
        self.core.chain_at_rung()
    }

    /// Phases launched but not yet scored (0, 1 or 2 — the double
    /// buffer never runs deeper).
    pub fn in_flight(&self) -> usize {
        self.launched - self.scored
    }

    /// β slice for the next phase to launch, or `None` once every
    /// configured round has been handed out. Panics if called with two
    /// phases already in flight — score the oldest one first.
    pub fn launch(&mut self, beta_scale: f64) -> Option<Vec<f32>> {
        if self.launched >= self.core.rounds() {
            return None;
        }
        assert!(self.in_flight() < 2, "pipeline depth is 2: score a phase before launching");
        self.launched += 1;
        Some(self.core.chain_betas(beta_scale))
    }

    /// Swap phase over the oldest in-flight phase's readback — the
    /// unmodified [`TemperingCore::finish_round`], consumed in strict
    /// phase order.
    pub fn score(&mut self, energies: &[f64], states: &[Vec<i8>]) {
        assert!(self.in_flight() > 0, "no phase in flight to score");
        self.core.finish_round(self.scored, energies, states);
        self.scored += 1;
    }

    /// Finalize into a [`TemperingRun`] (every launched phase must have
    /// been scored).
    pub fn into_run(self) -> TemperingRun {
        assert_eq!(self.launched, self.scored, "pipeline drained with phases still in flight");
        self.core.into_run()
    }

    /// Finalize even with phases still in flight, discarding their
    /// (unscored) readbacks. The elastic coordinator uses this when a
    /// gang member is lost mid-pipeline: the in-flight phase may
    /// include the dead shard's chains, so it cannot be scored — its
    /// sweeps are simply dropped and the survivors resume from the last
    /// *scored* phase.
    pub fn into_run_abandoning(self) -> TemperingRun {
        self.core.into_run()
    }
}

/// Run the pipelined (1-phase-lag) replica-exchange schedule against a
/// single sampler — the serial reference the overlapped sharded
/// execution is proven bit-identical to, and the `--pipeline` path for
/// a 1-die run. See [`PipelinedCore`] for the schedule semantics.
pub fn temper_pipelined<S: Sampler>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
) -> Result<TemperingRun> {
    temper_pipelined_observed(sampler, problem, params, beta_scale, |_, _, _| {})
}

/// [`temper_pipelined`] with the per-round observer of
/// [`temper_observed`]: `observe(round, states, chain_at_rung)` fires
/// as each phase is *scored* (one phase behind its sweep), with the
/// rung→chain map exactly as the swap phase will read it.
pub fn temper_pipelined_observed<S, F>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
    mut observe: F,
) -> Result<TemperingRun>
where
    S: Sampler,
    F: FnMut(usize, &[Vec<i8>], &[usize]),
{
    let mut core = PipelinedCore::new(params, sampler.batch())?;
    let readback = EnergyReadback::install(sampler, problem);
    // One sampler cannot literally overlap compute, but the *data flow*
    // of the distributed interleave is reproduced exactly: phase t is
    // launched (and swept) before phase t−1 is scored, so every β
    // slice, RNG draw and counter update happens against the same
    // inputs in the same order as in the sharded coordinator.
    let mut pending: Option<(Vec<f64>, Vec<Vec<i8>>)> = None;
    for round in 0..params.rounds {
        let betas = core.launch(beta_scale).expect("one launch per round");
        sampler.set_betas(&betas)?;
        sampler.sweeps(params.sweeps_per_round)?;
        let energies = readback.read(sampler, problem);
        let states = sampler.states();
        if let Some((pe, ps)) = pending.take() {
            observe(round - 1, &ps, core.chain_at_rung());
            core.score(&pe, &ps);
        }
        pending = Some((energies, states));
    }
    if let Some((pe, ps)) = pending.take() {
        observe(params.rounds - 1, &ps, core.chain_at_rung());
        core.score(&pe, &ps);
    }
    Ok(core.into_run())
}

/// Run replica exchange on a batched sampler. `beta_scale` converts
/// logical β to the chip knob exactly as in [`super::anneal`]; the swap
/// criterion uses logical β × logical energy, which equals chip-β ×
/// chip-energy because the scale cancels.
///
/// The sampler's first `ladder.len()` chains are the replicas; any extra
/// chains run at the hottest β as free scouts (they join the best-energy
/// search but not the swap dynamics).
pub fn temper<S: Sampler>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
) -> Result<TemperingRun> {
    temper_observed(sampler, problem, params, beta_scale, |_, _, _| {})
}

/// [`temper`] with a per-round observer `observe(round, states,
/// chain_at_rung)` called after each sweep phase — the hook the
/// statistical validation tests use to accumulate per-rung marginals.
pub fn temper_observed<S, F>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &TemperingParams,
    beta_scale: f64,
    mut observe: F,
) -> Result<TemperingRun>
where
    S: Sampler,
    F: FnMut(usize, &[Vec<i8>], &[usize]),
{
    let mut core = TemperingCore::new(params, sampler.batch())?;
    let readback = EnergyReadback::install(sampler, problem);
    for round in 0..params.rounds {
        // sweep phase
        sampler.set_betas(&core.chain_betas(beta_scale))?;
        sampler.sweeps(params.sweeps_per_round)?;
        let energies = readback.read(sampler, problem);
        let states = sampler.states();
        observe(round, &states, core.chain_at_rung());
        // swap phase
        core.finish_round(round, &energies, &states);
    }
    Ok(core.into_run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Personality;
    use crate::chimera::Topology;
    use crate::problems::sk;
    use crate::sampler::SoftwareSampler;

    fn glass_sampler(seed: u64, batch: usize) -> (SoftwareSampler, IsingProblem, f64) {
        let topo = Topology::new();
        let problem = sk::chimera_pm_j(&topo, seed);
        let personality = Personality::ideal(&topo);
        let (j, en, h, scale) = problem.to_codes(&topo).unwrap();
        let mut w = crate::analog::ProgrammedWeights::zeros(topo.edges.len());
        w.j_codes = j;
        w.enables = en;
        w.h_codes = h;
        let folded = personality.fold(&topo, &w);
        let mut s = SoftwareSampler::new(batch, seed);
        s.load(&folded);
        (s, problem, scale)
    }

    #[test]
    fn tempering_lowers_energy_on_a_glass() {
        let (mut s, problem, scale) = glass_sampler(7, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 48,
            record_every: 4,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        let first_mean = run.trace.rows.first().unwrap().2;
        assert!(
            run.best_energy < first_mean - 50.0,
            "tempering should drop energy substantially: {first_mean} → {}",
            run.best_energy
        );
        assert_eq!(run.best_state.len(), crate::N_SPINS);
        assert_eq!(run.total_sweeps, 96);
    }

    #[test]
    fn swaps_are_attempted_and_some_accepted() {
        let (mut s, problem, scale) = glass_sampler(3, 16);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.3, 2.0, 16),
            sweeps_per_round: 2,
            rounds: 60,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        let attempts: u64 = run.swaps.attempts.iter().sum();
        // 15 pairs, alternating parity → ~450 attempts over 60 rounds
        assert!(attempts > 300, "attempts {attempts}");
        assert!(run.swaps.mean_acceptance() > 0.0, "no swap ever accepted");
    }

    #[test]
    fn ladder_larger_than_batch_is_rejected() {
        let (mut s, problem, scale) = glass_sampler(1, 4);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            ..Default::default()
        };
        assert!(temper(&mut s, &problem, &params, scale).is_err());
    }

    #[test]
    fn adaptation_keeps_endpoints_and_order() {
        let (mut s, problem, scale) = glass_sampler(5, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 40,
            adapt_every: 10,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        assert!((run.ladder.hottest() - 0.1).abs() < 1e-12);
        assert!((run.ladder.coldest() - 4.0).abs() < 1e-12);
        assert!(run.ladder.betas.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn flux_is_recorded_with_pinned_endpoints() {
        let (mut s, problem, scale) = glass_sampler(3, 16);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.3, 2.0, 8),
            sweeps_per_round: 2,
            rounds: 80,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        // one visit per rung per round
        let visits = run.flux.up[0] + run.flux.down[0] + run.flux.unlabeled[0];
        assert_eq!(visits, 80);
        // endpoints are labeled by construction after the first round
        assert_eq!(run.flux.fraction_up(0), 1.0, "hot end must host up-movers only");
        assert_eq!(run.flux.fraction_up(7), 0.0, "cold end must host down-movers only");
        // once warmed up, most visits carry a label
        assert!(run.flux.labeled_fraction() > 0.5, "{}", run.flux.labeled_fraction());
        let f = run.flux.f_profile();
        assert_eq!(f.len(), 8);
        assert!(f.iter().all(|v| (0.0..=1.0).contains(v)), "{f:?}");
    }

    #[test]
    fn flux_tuning_respaces_the_ladder_in_run() {
        let (mut s, problem, scale) = glass_sampler(5, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 80,
            adapt_every: 20,
            tuning: LadderTuning::RoundTripFlux,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        assert!((run.ladder.hottest() - 0.1).abs() < 1e-12);
        assert!((run.ladder.coldest() - 4.0).abs() < 1e-12);
        assert!(run.ladder.betas.windows(2).all(|w| w[1] > w[0]));
        assert_ne!(
            run.ladder.betas,
            BetaLadder::geometric(0.1, 4.0, 8).betas,
            "flux feedback never moved the ladder"
        );
    }

    #[test]
    fn tuning_off_ignores_adapt_every() {
        let (mut s, problem, scale) = glass_sampler(5, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 40,
            adapt_every: 10,
            tuning: LadderTuning::Off,
            ..Default::default()
        };
        let run = temper(&mut s, &problem, &params, scale).unwrap();
        assert_eq!(run.ladder.betas, BetaLadder::geometric(0.1, 4.0, 8).betas);
    }

    #[test]
    fn core_rejects_bad_assignments() {
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.2, 2.0, 4),
            ..Default::default()
        };
        // duplicate chain
        assert!(TemperingCore::with_assignment(&params, 8, vec![0, 1, 1, 3]).is_err());
        // chain out of range
        assert!(TemperingCore::with_assignment(&params, 4, vec![0, 1, 2, 4]).is_err());
        // wrong arity
        assert!(TemperingCore::with_assignment(&params, 8, vec![0, 1, 2]).is_err());
        // a permuted assignment is fine
        assert!(TemperingCore::with_assignment(&params, 8, vec![5, 1, 7, 3]).is_ok());
    }

    #[test]
    fn core_scout_chains_run_at_the_hottest_beta() {
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.5, 2.0, 2),
            ..Default::default()
        };
        let core = TemperingCore::with_assignment(&params, 4, vec![2, 0]).unwrap();
        let betas = core.chain_betas(1.0);
        assert_eq!(betas.len(), 4);
        assert!((betas[2] - 0.5).abs() < 1e-6, "rung 0 chain");
        assert!((betas[0] - 2.0).abs() < 1e-6, "rung 1 chain");
        // chains 1 and 3 are scouts: hottest β
        assert!((betas[1] - 0.5).abs() < 1e-6);
        assert!((betas[3] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn pipelined_schedule_lowers_energy_and_is_deterministic() {
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.1, 4.0, 8),
            sweeps_per_round: 2,
            rounds: 48,
            record_every: 4,
            ..Default::default()
        };
        let (mut s1, problem, scale) = glass_sampler(7, 8);
        let run1 = temper_pipelined(&mut s1, &problem, &params, scale).unwrap();
        let first_mean = run1.trace.rows.first().unwrap().2;
        assert!(
            run1.best_energy < first_mean - 50.0,
            "pipelined tempering should drop energy: {first_mean} → {}",
            run1.best_energy
        );
        assert_eq!(run1.total_sweeps, 96);
        // same seeds, fresh sampler → bit-identical run
        let (mut s2, problem2, scale2) = glass_sampler(7, 8);
        let run2 = temper_pipelined(&mut s2, &problem2, &params, scale2).unwrap();
        assert_eq!(run1.best_energy.to_bits(), run2.best_energy.to_bits());
        assert_eq!(run1.best_state, run2.best_state);
        assert_eq!(run1.trace.rows, run2.trace.rows);
        assert_eq!(run1.swaps.accepts, run2.swaps.accepts);
        assert_eq!(run1.swaps.round_trips, run2.swaps.round_trips);
    }

    #[test]
    fn pipelined_observer_lags_one_phase() {
        let (mut s, problem, scale) = glass_sampler(2, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.2, 2.0, 4),
            sweeps_per_round: 1,
            rounds: 12,
            ..Default::default()
        };
        let mut seen = 0usize;
        temper_pipelined_observed(&mut s, &problem, &params, scale, |round, states, map| {
            assert_eq!(round, seen);
            assert_eq!(states.len(), 8);
            assert_eq!(map.len(), 4);
            seen += 1;
        })
        .unwrap();
        assert_eq!(seen, 12, "every phase is eventually scored and observed");
    }

    #[test]
    #[should_panic(expected = "pipeline depth is 2")]
    fn pipelined_core_refuses_a_third_in_flight_phase() {
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.2, 2.0, 4),
            ..Default::default()
        };
        let mut core = PipelinedCore::new(&params, 8).unwrap();
        let _ = core.launch(1.0);
        let _ = core.launch(1.0);
        let _ = core.launch(1.0); // must panic: nothing scored yet
    }

    #[test]
    fn observer_sees_every_round() {
        let (mut s, problem, scale) = glass_sampler(2, 8);
        let params = TemperingParams {
            ladder: BetaLadder::geometric(0.2, 2.0, 4),
            sweeps_per_round: 1,
            rounds: 12,
            ..Default::default()
        };
        let mut seen = 0usize;
        temper_observed(&mut s, &problem, &params, scale, |round, states, map| {
            assert_eq!(round, seen);
            assert_eq!(states.len(), 8);
            assert_eq!(map.len(), 4);
            seen += 1;
        })
        .unwrap();
        assert_eq!(seen, 12);
    }
}
