//! The annealing driver: ramp β over a sampler while recording the
//! energy trace (the Fig 9a experiment).

use anyhow::Result;

use crate::metrics::EnergyTrace;
use crate::problems::IsingProblem;
use crate::sampler::Sampler;

/// Annealing run parameters.
#[derive(Debug, Clone, Copy)]
pub struct AnnealParams {
    /// The β ramp shape (V_temp schedule).
    pub schedule: super::BetaSchedule,
    /// Number of β steps in the ramp.
    pub steps: usize,
    /// Sweeps per β step.
    pub sweeps_per_step: usize,
    /// Record the trace every `record_every` steps.
    pub record_every: usize,
}

impl Default for AnnealParams {
    fn default() -> Self {
        Self {
            schedule: super::BetaSchedule::Geometric { b0: 0.1, b1: 5.0 },
            steps: 64,
            sweeps_per_step: 8,
            record_every: 1,
        }
    }
}

/// Run one anneal. `beta_scale` converts logical β to the chip knob
/// (problems quantized to codes need β_chip = β_logical × scale; see
/// [`IsingProblem::beta_for`]). Returns the energy trace and the best
/// states seen per chain.
pub fn anneal<S: Sampler>(
    sampler: &mut S,
    problem: &IsingProblem,
    params: &AnnealParams,
    beta_scale: f64,
) -> Result<(EnergyTrace, Vec<(f64, Vec<i8>)>)> {
    let mut trace = EnergyTrace::default();
    let batch = sampler.batch();
    let mut best: Vec<(f64, Vec<i8>)> = vec![(f64::INFINITY, Vec::new()); batch];
    let mut sweeps_done = 0u64;
    for k in 0..params.steps {
        let beta_logical = params.schedule.beta_at(k, params.steps);
        sampler.set_beta((beta_logical * beta_scale) as f32);
        sampler.sweeps(params.sweeps_per_step)?;
        sweeps_done += params.sweeps_per_step as u64;
        let states = sampler.states();
        let energies: Vec<f64> = states.iter().map(|s| problem.energy(s)).collect();
        for (c, (e, s)) in energies.iter().zip(&states).enumerate() {
            if *e < best[c].0 {
                best[c] = (*e, s.clone());
            }
        }
        if k % params.record_every == 0 || k == params.steps - 1 {
            let mean = energies.iter().sum::<f64>() / energies.len() as f64;
            let min = energies.iter().cloned().fold(f64::INFINITY, f64::min);
            trace.push(sweeps_done, beta_logical, mean, min);
        }
    }
    Ok((trace, best))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Personality;
    use crate::annealing::BetaSchedule;
    use crate::chimera::Topology;
    use crate::problems::sk;
    use crate::sampler::SoftwareSampler;

    #[test]
    fn annealing_lowers_energy_on_a_glass() {
        let topo = Topology::new();
        let problem = sk::chimera_pm_j(&topo, 7);
        let personality = Personality::ideal(&topo);
        let (j, en, h, scale) = problem.to_codes(&topo).unwrap();
        let mut w = crate::analog::ProgrammedWeights::zeros(topo.edges.len());
        w.j_codes = j;
        w.enables = en;
        w.h_codes = h;
        let folded = personality.fold(&topo, &w);
        let mut s = SoftwareSampler::new(4, 1);
        s.load(&folded);
        let params = AnnealParams {
            schedule: BetaSchedule::Geometric { b0: 0.1, b1: 4.0 },
            steps: 24,
            sweeps_per_step: 4,
            record_every: 1,
        };
        let (trace, best) = anneal(&mut s, &problem, &params, 1.0 / scale * scale).unwrap();
        // note: codes quantize J to ±127/127 = ±1 exactly, so scale = 1.
        let first = trace.rows.first().unwrap().2;
        let last_min = trace.final_min().unwrap();
        assert!(
            last_min < first - 50.0,
            "annealing should drop energy substantially: {first} → {last_min}"
        );
        assert!(best.iter().all(|(e, s)| *e <= last_min + 1e-9 || !s.is_empty()));
    }
}
