//! β (inverse-temperature) schedules — the V_temp ramp shapes — and the
//! β-ladders the replica-exchange engine runs on.

/// An annealing schedule mapping progress ∈ [0, 1] to β.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BetaSchedule {
    /// Fixed β (free-running sampling).
    Constant(f64),
    /// Linear ramp β₀ → β₁.
    Linear { b0: f64, b1: f64 },
    /// Geometric ramp β₀ → β₁ (equal multiplicative steps — the classic
    /// SA choice; matches a linearly-ramped V_temp through the tanh
    /// stage's exponential transconductance).
    Geometric { b0: f64, b1: f64 },
}

impl BetaSchedule {
    /// β at progress t ∈ [0, 1].
    pub fn beta(&self, t: f64) -> f64 {
        let t = t.clamp(0.0, 1.0);
        match *self {
            Self::Constant(b) => b,
            Self::Linear { b0, b1 } => b0 + (b1 - b0) * t,
            Self::Geometric { b0, b1 } => b0 * (b1 / b0).powf(t),
        }
    }

    /// β at step `k` of `n` (progress = k/(n−1)).
    pub fn beta_at(&self, k: usize, n: usize) -> f64 {
        if n <= 1 {
            return self.beta(1.0);
        }
        self.beta(k as f64 / (n - 1) as f64)
    }

    /// The ramp's end point, β(1).
    pub fn final_beta(&self) -> f64 {
        self.beta(1.0)
    }
}

/// A fixed β-ladder for replica exchange: one rung per replica, sorted
/// ascending (rung 0 is the hottest / most-mobile replica, the last rung
/// the coldest / most-greedy one).
///
/// Constructed geometrically — the spacing that equalizes swap
/// acceptance when the specific heat is roughly constant — and optionally
/// re-spaced from *measured* feedback: acceptance rates with
/// [`BetaLadder::adapted`], or the round-trip flux profile with
/// [`BetaLadder::flux_respaced`] (rungs crowd into the gaps where swaps
/// are rare or diffusion stalls, typically around a phase transition).
///
/// The three stages of a ladder's life — geometric guess, acceptance
/// adaptation, flux tuning:
///
/// ```
/// use pchip::annealing::BetaLadder;
///
/// // 1. geometric guess over the β span
/// let ladder = BetaLadder::geometric(0.1, 4.0, 6);
///
/// // 2. re-space from measured pair acceptance (cheap feedback)
/// let adapted = ladder.adapted(&[0.5, 0.4, 0.1, 0.4, 0.5]);
///
/// // 3. re-space from the measured up-mover profile f(β) — what
/// //    `tune_ladder` iterates to convergence (round-trip flux)
/// let tuned = adapted.flux_respaced(&[1.0, 0.8, 0.55, 0.45, 0.2, 0.0]);
///
/// for l in [&ladder, &adapted, &tuned] {
///     assert_eq!(l.len(), 6);
///     assert_eq!(l.hottest(), 0.1);
///     assert_eq!(l.coldest(), 4.0);
///     assert!(l.betas.windows(2).all(|w| w[1] > w[0]));
/// }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct BetaLadder {
    /// Rung temperatures, strictly ascending.
    pub betas: Vec<f64>,
}

impl BetaLadder {
    /// Geometric ladder of `k ≥ 2` rungs from β₀ (hot) to β₁ (cold).
    pub fn geometric(b0: f64, b1: f64, k: usize) -> Self {
        assert!(k >= 2, "a ladder needs at least two rungs, got {k}");
        assert!(b0 > 0.0 && b1 > b0, "need 0 < b0 < b1, got {b0}..{b1}");
        let sched = BetaSchedule::Geometric { b0, b1 };
        Self { betas: (0..k).map(|j| sched.beta_at(j, k)).collect() }
    }

    /// Sample any [`BetaSchedule`] at `k` equally-spaced progress points.
    pub fn from_schedule(sched: BetaSchedule, k: usize) -> Self {
        assert!(k >= 2, "a ladder needs at least two rungs, got {k}");
        let betas: Vec<f64> = (0..k).map(|j| sched.beta_at(j, k)).collect();
        assert!(
            betas.windows(2).all(|w| w[1] > w[0]),
            "schedule must be strictly increasing to form a ladder"
        );
        Self { betas }
    }

    /// Number of rungs (replicas).
    pub fn len(&self) -> usize {
        self.betas.len()
    }

    /// Whether the ladder has no rungs (never true for a constructed
    /// ladder — kept for the `len`/`is_empty` convention).
    pub fn is_empty(&self) -> bool {
        self.betas.is_empty()
    }

    /// Hottest rung (smallest β).
    pub fn hottest(&self) -> f64 {
        self.betas[0]
    }

    /// Coldest rung (largest β) — the rung whose marginals answer the
    /// sampling question.
    pub fn coldest(&self) -> f64 {
        *self.betas.last().unwrap()
    }

    /// Partition the ladder into `shards` contiguous rung ranges — the
    /// shard plan of the cross-die tempering coordinator
    /// ([`crate::coordinator::run_sharded_tempering`]). Rung counts are
    /// balanced (sizes differ by at most one, larger shards first), the
    /// ranges are in ladder order, every rung lands in exactly one
    /// range, the first range starts at the hottest rung and the last
    /// ends at the coldest.
    ///
    /// Panics unless `1 ≤ shards ≤ len()`.
    pub fn partition(&self, shards: usize) -> Vec<std::ops::Range<usize>> {
        let k = self.len();
        assert!(
            shards >= 1 && shards <= k,
            "need between 1 and {k} shards for a {k}-rung ladder, got {shards}"
        );
        let base = k / shards;
        let rem = k % shards;
        let mut out = Vec::with_capacity(shards);
        let mut start = 0;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            out.push(start..start + len);
            start += len;
        }
        out
    }

    /// Re-space the interior rungs from measured adjacent-pair swap
    /// acceptance rates (`acceptance.len() == len() − 1`).
    ///
    /// Each gap is assigned a "resistance" ∝ 1/acceptance; new rungs are
    /// placed at equal cumulative resistance, interpolating in ln β.
    /// Endpoints are pinned, ordering is preserved, and a ladder whose
    /// acceptance is already uniform comes back unchanged.
    ///
    /// Degenerate input is clamped to a sane re-spacing rather than
    /// collapsing rung gaps: rates are clamped to `[0.02, 1.0]` (an
    /// all-rejected gap pulls hard — 50× — but not infinitely, and an
    /// all-zero vector is uniform, i.e. a fixed point), non-finite rates
    /// are treated as carrying no information, and the result is
    /// guaranteed strictly increasing with both endpoints exact.
    ///
    /// ```
    /// use pchip::annealing::BetaLadder;
    ///
    /// let ladder = BetaLadder::geometric(0.1, 4.0, 6);
    /// // measured acceptance: the gap between rungs 2 and 3 is starving
    /// let tuned = ladder.adapted(&[0.6, 0.6, 0.05, 0.6, 0.6]);
    /// // rungs crowd into the starving gap; endpoints stay pinned
    /// assert!(tuned.betas[3] - tuned.betas[2] < ladder.betas[3] - ladder.betas[2]);
    /// assert_eq!(tuned.hottest(), ladder.hottest());
    /// assert_eq!(tuned.coldest(), ladder.coldest());
    /// ```
    pub fn adapted(&self, acceptance: &[f64]) -> Self {
        let k = self.betas.len();
        assert_eq!(acceptance.len(), k - 1, "need one acceptance rate per adjacent pair");
        let resist: Vec<f64> = acceptance
            .iter()
            .map(|&a| {
                if a.is_finite() {
                    1.0 / a.clamp(0.02, 1.0)
                } else {
                    // a NaN / infinite rate carries no information: pass
                    // it through so `respace_weighted` fills it with the
                    // mean of the *measured* resistances — neutral, not
                    // biased toward (or away from) the unknown gap
                    f64::NAN
                }
            })
            .collect();
        self.respace_weighted(&resist)
    }

    /// Re-space the rungs from a measured round-trip flux profile
    /// `fraction_up` — per-rung fraction of *up-moving* replicas
    /// ([`crate::metrics::FluxStats::f_profile`]), `len() == len()` —
    /// the Katzgraber feedback-optimization step.
    ///
    /// In the random-walk picture each replica diffuses along the ladder
    /// with local diffusivity `D(β)`; the steady-state up-mover fraction
    /// satisfies `j = D(β) · η(β) · df/dβ` with constant round-trip flux
    /// `j` and rung density `η`. The round-trip rate is maximized by
    /// `η_opt ∝ 1/√D ∝ √(η_meas · df/dβ)`, which integrated over a gap
    /// gives the gap a weight `√Δf`. New rungs are placed at equal
    /// cumulative `√Δf` (interpolating in ln β), so a profile that
    /// already falls linearly in rung index — the optimality condition —
    /// is a fixed point.
    ///
    /// Flat or noise-inverted stretches of the profile are clamped to a
    /// small positive weight so every gap survives; endpoints stay
    /// pinned and the result is strictly increasing.
    ///
    /// ```
    /// use pchip::annealing::BetaLadder;
    ///
    /// let ladder = BetaLadder::geometric(0.1, 4.0, 5);
    /// // f plateaus across the middle rungs (flat stretch = diffusion
    /// // bottleneck): rungs will crowd into the plateau
    /// let tuned = ladder.flux_respaced(&[1.0, 0.55, 0.5, 0.45, 0.0]);
    /// // a linear profile is the optimum and therefore a fixed point
    /// let fixed = ladder.flux_respaced(&[1.0, 0.75, 0.5, 0.25, 0.0]);
    /// for (a, b) in ladder.betas.iter().zip(&fixed.betas) {
    ///     assert!((a - b).abs() < 1e-9);
    /// }
    /// assert_eq!(tuned.len(), ladder.len());
    /// assert!(tuned.betas.windows(2).all(|w| w[1] > w[0]));
    /// ```
    pub fn flux_respaced(&self, fraction_up: &[f64]) -> Self {
        let k = self.betas.len();
        assert_eq!(fraction_up.len(), k, "need one f(β) sample per rung");
        // Δf across each gap, clamped so flat / inverted (noisy)
        // stretches keep a small weight instead of collapsing
        let floor = 0.01 / (k - 1) as f64;
        let weights: Vec<f64> = fraction_up
            .windows(2)
            .map(|w| {
                let df = w[0] - w[1];
                let df = if df.is_finite() { df } else { 0.0 };
                df.max(floor).sqrt()
            })
            .collect();
        self.respace_weighted(&weights)
    }

    /// The same ladder re-sampled to `k ≥ 2` rungs: piecewise-linear
    /// interpolation of the current rung profile in ln β, endpoints
    /// pinned — the auto-sizing step of [`crate::annealing::tune_ladder`]
    /// (grow when the acceptance bottleneck is starving, shrink when
    /// adjacent rungs are redundant). The *shape* the previous feedback
    /// rounds learned survives the resize; only the density changes.
    pub fn resized(&self, k: usize) -> Self {
        assert!(k >= 2, "a ladder needs at least two rungs, got {k}");
        let n = self.len();
        if k == n {
            return self.clone();
        }
        let lnb: Vec<f64> = self.betas.iter().map(|b| b.ln()).collect();
        let mut betas = Vec::with_capacity(k);
        for j in 0..k {
            let t = j as f64 / (k - 1) as f64 * (n - 1) as f64;
            let g = (t.floor() as usize).min(n - 2);
            let frac = t - g as f64;
            betas.push((lnb[g] + frac * (lnb[g + 1] - lnb[g])).exp());
        }
        betas[0] = self.betas[0];
        betas[k - 1] = self.betas[n - 1];
        Self { betas }
    }

    /// Shared re-spacing core: place `len()` rungs at equal cumulative
    /// per-gap `weights` (`len() − 1` of them), interpolating in ln β.
    /// Non-finite / non-positive weights are replaced by the mean of the
    /// informative ones; endpoints are pinned exactly and strict
    /// monotonicity is enforced, so no input can collapse two rungs.
    fn respace_weighted(&self, weights: &[f64]) -> Self {
        let k = self.betas.len();
        debug_assert_eq!(weights.len(), k - 1);
        let finite: Vec<f64> =
            weights.iter().copied().filter(|w| w.is_finite() && *w > 0.0).collect();
        let fill = if finite.is_empty() {
            1.0
        } else {
            finite.iter().sum::<f64>() / finite.len() as f64
        };
        let w: Vec<f64> =
            weights.iter().map(|&x| if x.is_finite() && x > 0.0 { x } else { fill }).collect();
        let mut cum = Vec::with_capacity(k);
        cum.push(0.0);
        for &r in &w {
            cum.push(cum.last().unwrap() + r);
        }
        let total = *cum.last().unwrap();
        let lnb: Vec<f64> = self.betas.iter().map(|b| b.ln()).collect();
        let mut out = Vec::with_capacity(k);
        for j in 0..k {
            let target = total * j as f64 / (k - 1) as f64;
            let gap = cum.windows(2).position(|c| target <= c[1] + 1e-12).unwrap_or(k - 2);
            let frac = ((target - cum[gap]) / w[gap].max(1e-300)).clamp(0.0, 1.0);
            out.push(lnb[gap] + frac * (lnb[gap + 1] - lnb[gap]));
        }
        // pin endpoints, then force strict monotonicity: a degenerate
        // weight profile may park two targets on the same spot, and two
        // coincident rungs would freeze their swap pair forever
        out[0] = lnb[0];
        out[k - 1] = lnb[k - 1];
        let eps = (lnb[k - 1] - lnb[0]) * 1e-9 / k as f64;
        for j in 1..k {
            if out[j] <= out[j - 1] {
                out[j] = out[j - 1] + eps;
            }
        }
        out[k - 1] = lnb[k - 1];
        for j in (1..k - 1).rev() {
            if out[j] >= out[j + 1] {
                out[j] = out[j + 1] - eps;
            }
        }
        let mut betas: Vec<f64> = out.iter().map(|l| l.exp()).collect();
        betas[0] = self.betas[0];
        betas[k - 1] = self.betas[k - 1];
        Self { betas }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let lin = BetaSchedule::Linear { b0: 0.1, b1: 5.0 };
        assert_eq!(lin.beta(0.0), 0.1);
        assert_eq!(lin.beta(1.0), 5.0);
        let geo = BetaSchedule::Geometric { b0: 0.1, b1: 5.0 };
        assert!((geo.beta(0.0) - 0.1).abs() < 1e-12);
        assert!((geo.beta(1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_is_multiplicative() {
        let geo = BetaSchedule::Geometric { b0: 1.0, b1: 16.0 };
        let r1 = geo.beta(0.25) / geo.beta(0.0);
        let r2 = geo.beta(0.5) / geo.beta(0.25);
        assert!((r1 - r2).abs() < 1e-9);
    }

    #[test]
    fn monotone_increasing() {
        for sched in [
            BetaSchedule::Linear { b0: 0.2, b1: 4.0 },
            BetaSchedule::Geometric { b0: 0.2, b1: 4.0 },
        ] {
            let mut prev = 0.0;
            for k in 0..=10 {
                let b = sched.beta_at(k, 11);
                assert!(b >= prev);
                prev = b;
            }
        }
    }

    #[test]
    fn ladder_geometric_endpoints_and_order() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        assert_eq!(l.len(), 8);
        assert!((l.hottest() - 0.1).abs() < 1e-12);
        assert!((l.coldest() - 4.0).abs() < 1e-12);
        assert!(l.betas.windows(2).all(|w| w[1] > w[0]));
        // geometric: constant ratio between rungs
        let r0 = l.betas[1] / l.betas[0];
        for w in l.betas.windows(2) {
            assert!((w[1] / w[0] - r0).abs() < 1e-9);
        }
    }

    #[test]
    fn ladder_uniform_acceptance_is_a_fixed_point() {
        let l = BetaLadder::geometric(0.2, 3.0, 6);
        let a = l.adapted(&[0.4; 5]);
        for (x, y) in l.betas.iter().zip(&a.betas) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn ladder_adapts_toward_the_bottleneck() {
        // gap 0 rejects everything → rungs must crowd into it
        let l = BetaLadder::geometric(0.5, 2.0, 5);
        let a = l.adapted(&[0.02, 0.9, 0.9, 0.9]);
        let old_gap0 = l.betas[1] - l.betas[0];
        let new_gap0 = a.betas[1] - a.betas[0];
        assert!(new_gap0 < old_gap0, "bottleneck gap should shrink: {old_gap0} → {new_gap0}");
        // endpoints pinned, order preserved
        assert_eq!(a.betas[0], l.betas[0]);
        assert_eq!(a.betas[4], l.betas[4]);
        assert!(a.betas.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn adapted_all_zero_acceptance_is_sane_not_collapsed() {
        // every pair fully rejecting: no gradient to follow — the clamp
        // makes the resistance uniform, so the ladder must come back
        // unchanged instead of collapsing rungs together
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        let a = l.adapted(&[0.0; 7]);
        for (x, y) in l.betas.iter().zip(&a.betas) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn adapted_single_zero_gap_keeps_strict_order_and_endpoints() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        let mut rates = [0.8; 7];
        rates[3] = 0.0;
        let a = l.adapted(&rates);
        assert_eq!(a.betas[0], l.betas[0]);
        assert_eq!(a.betas[7], l.betas[7]);
        assert!(a.betas.windows(2).all(|w| w[1] > w[0]), "rung gap collapsed: {:?}", a.betas);
        // the dead pair pulls rungs toward it, but the 50× clamp bounds
        // how far: no surviving gap may collapse below 1/1000 of the
        // ln-β span
        let span = l.coldest().ln() - l.hottest().ln();
        for w in a.betas.windows(2) {
            assert!(w[1].ln() - w[0].ln() > span / 1000.0, "collapsed gap in {:?}", a.betas);
        }
    }

    #[test]
    fn adapted_non_finite_rates_are_ignored_not_poisonous() {
        let l = BetaLadder::geometric(0.2, 3.0, 6);
        let a = l.adapted(&[f64::NAN, 0.4, f64::INFINITY, 0.4, f64::NAN]);
        assert!(a.betas.iter().all(|b| b.is_finite()), "NaN leaked: {:?}", a.betas);
        assert_eq!(a.betas[0], l.betas[0]);
        assert_eq!(a.betas[5], l.betas[5]);
        assert!(a.betas.windows(2).all(|w| w[1] > w[0]));
        // "no information" must mean *neutral*: with every measured rate
        // equal, the unmeasured gaps fill with the same resistance and
        // the ladder is a fixed point — rungs are not pulled toward or
        // away from the unknown gaps
        for (x, y) in l.betas.iter().zip(&a.betas) {
            assert!((x - y).abs() < 1e-9, "unknown gap biased the ladder: {x} vs {y}");
        }
    }

    #[test]
    fn flux_respaced_crowds_rungs_into_the_plateau() {
        // f plateaus across the middle gap: the diffusion bottleneck —
        // rungs elsewhere carry the f drop, so the bottleneck gap must
        // shrink relative to the rest of the ladder
        let l = BetaLadder::geometric(0.1, 4.0, 5);
        let t = l.flux_respaced(&[1.0, 0.55, 0.5, 0.45, 0.0]);
        let old_mid = l.betas[3].ln() - l.betas[1].ln();
        let new_mid = t.betas[3].ln() - t.betas[1].ln();
        assert!(new_mid < old_mid, "plateau region should shrink: {old_mid} → {new_mid}");
        assert_eq!(t.betas[0], l.betas[0]);
        assert_eq!(t.betas[4], l.betas[4]);
    }

    /// Property: flux re-spacing always pins the endpoints and returns a
    /// strictly increasing ladder, for any profile — monotone, noisy,
    /// flat, or outright degenerate (all-equal f).
    #[test]
    fn prop_flux_respaced_endpoints_pinned_and_strictly_monotone() {
        crate::util::prop::check("flux respacing", 300, |rng| {
            let k = rng.below(20) + 2;
            let ladder = BetaLadder::geometric(0.05 + rng.uniform(), 3.0 + 4.0 * rng.uniform(), k);
            // random profile: sometimes a proper decreasing one,
            // sometimes pure noise, sometimes completely flat
            let f: Vec<f64> = match rng.below(3) {
                0 => (0..k).map(|j| 1.0 - j as f64 / (k - 1) as f64).collect(),
                1 => (0..k).map(|_| rng.uniform()).collect(),
                _ => vec![0.5; k],
            };
            let t = ladder.flux_respaced(&f);
            assert_eq!(t.len(), k);
            assert_eq!(t.betas[0], ladder.betas[0], "hot endpoint moved");
            assert_eq!(t.betas[k - 1], ladder.betas[k - 1], "cold endpoint moved");
            assert!(
                t.betas.windows(2).all(|w| w[1] > w[0]),
                "not strictly increasing: {:?} from f={f:?}",
                t.betas
            );
        });
    }

    #[test]
    fn resized_preserves_endpoints_and_order() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        for k in [2usize, 3, 7, 8, 9, 16] {
            let r = l.resized(k);
            assert_eq!(r.len(), k);
            assert_eq!(r.betas[0], l.betas[0]);
            assert_eq!(*r.betas.last().unwrap(), *l.betas.last().unwrap());
            assert!(r.betas.windows(2).all(|w| w[1] > w[0]), "k={k}: {:?}", r.betas);
        }
        // resizing to the same K is the identity
        assert_eq!(l.resized(8).betas, l.betas);
    }

    #[test]
    fn resized_of_geometric_stays_geometric() {
        // a geometric ladder is linear in ln β, so re-sampling it at any
        // K must reproduce the geometric ladder at that K
        let l = BetaLadder::geometric(0.1, 4.0, 6);
        let r = l.resized(11);
        let want = BetaLadder::geometric(0.1, 4.0, 11);
        for (x, y) in r.betas.iter().zip(&want.betas) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn ladder_from_schedule_matches_geometric() {
        let a = BetaLadder::geometric(0.1, 4.0, 7);
        let b = BetaLadder::from_schedule(BetaSchedule::Geometric { b0: 0.1, b1: 4.0 }, 7);
        for (x, y) in a.betas.iter().zip(&b.betas) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    /// Property: a partition covers every rung exactly once, in order,
    /// with the endpoints pinned (first range starts at rung 0, last
    /// ends at the coldest rung) and balanced sizes.
    #[test]
    fn prop_partition_covers_every_rung_once_in_order() {
        crate::util::prop::check("ladder partition", 300, |rng| {
            let k = rng.below(30) + 2;
            let shards = rng.below(k) + 1;
            let ladder = BetaLadder::geometric(0.1, 4.0, k);
            let ranges = ladder.partition(shards);
            assert_eq!(ranges.len(), shards);
            // contiguous, ordered, endpoints pinned
            assert_eq!(ranges[0].start, 0, "first shard must start at the hottest rung");
            assert_eq!(ranges[shards - 1].end, k, "last shard must end at the coldest rung");
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile the ladder");
            }
            // every rung exactly once, every shard non-empty, balanced
            let mut covered = vec![0usize; k];
            for r in &ranges {
                assert!(!r.is_empty(), "empty shard in {ranges:?}");
                assert!(r.len() <= k / shards + 1, "unbalanced shard in {ranges:?}");
                for rung in r.clone() {
                    covered[rung] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "rung covered ≠ once: {covered:?}");
        });
    }

    #[test]
    fn partition_single_shard_is_the_whole_ladder() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        assert_eq!(l.partition(1), vec![0..8]);
        assert_eq!(l.partition(8), (0..8).map(|i| i..i + 1).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn partition_rejects_more_shards_than_rungs() {
        BetaLadder::geometric(0.1, 4.0, 4).partition(5);
    }

    #[test]
    fn clamps_out_of_range_progress() {
        let lin = BetaSchedule::Linear { b0: 1.0, b1: 2.0 };
        assert_eq!(lin.beta(-0.5), 1.0);
        assert_eq!(lin.beta(1.5), 2.0);
        assert_eq!(lin.beta_at(0, 1), 2.0);
    }
}
