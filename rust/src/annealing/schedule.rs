//! β (inverse-temperature) schedules — the V_temp ramp shapes — and the
//! β-ladders the replica-exchange engine runs on.

/// An annealing schedule mapping progress ∈ [0, 1] to β.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BetaSchedule {
    /// Fixed β (free-running sampling).
    Constant(f64),
    /// Linear ramp β₀ → β₁.
    Linear { b0: f64, b1: f64 },
    /// Geometric ramp β₀ → β₁ (equal multiplicative steps — the classic
    /// SA choice; matches a linearly-ramped V_temp through the tanh
    /// stage's exponential transconductance).
    Geometric { b0: f64, b1: f64 },
}

impl BetaSchedule {
    /// β at progress t ∈ [0, 1].
    pub fn beta(&self, t: f64) -> f64 {
        let t = t.clamp(0.0, 1.0);
        match *self {
            Self::Constant(b) => b,
            Self::Linear { b0, b1 } => b0 + (b1 - b0) * t,
            Self::Geometric { b0, b1 } => b0 * (b1 / b0).powf(t),
        }
    }

    /// β at step `k` of `n` (progress = k/(n−1)).
    pub fn beta_at(&self, k: usize, n: usize) -> f64 {
        if n <= 1 {
            return self.beta(1.0);
        }
        self.beta(k as f64 / (n - 1) as f64)
    }

    pub fn final_beta(&self) -> f64 {
        self.beta(1.0)
    }
}

/// A fixed β-ladder for replica exchange: one rung per replica, sorted
/// ascending (rung 0 is the hottest / most-mobile replica, the last rung
/// the coldest / most-greedy one).
///
/// Constructed geometrically — the spacing that equalizes swap
/// acceptance when the specific heat is roughly constant — and optionally
/// re-spaced from *measured* acceptance rates with [`BetaLadder::adapted`]
/// (feedback-optimized parallel tempering: rungs crowd into the gaps
/// where swaps are rare, typically around a phase transition).
#[derive(Debug, Clone, PartialEq)]
pub struct BetaLadder {
    /// Rung temperatures, strictly ascending.
    pub betas: Vec<f64>,
}

impl BetaLadder {
    /// Geometric ladder of `k ≥ 2` rungs from β₀ (hot) to β₁ (cold).
    pub fn geometric(b0: f64, b1: f64, k: usize) -> Self {
        assert!(k >= 2, "a ladder needs at least two rungs, got {k}");
        assert!(b0 > 0.0 && b1 > b0, "need 0 < b0 < b1, got {b0}..{b1}");
        let sched = BetaSchedule::Geometric { b0, b1 };
        Self { betas: (0..k).map(|j| sched.beta_at(j, k)).collect() }
    }

    /// Sample any [`BetaSchedule`] at `k` equally-spaced progress points.
    pub fn from_schedule(sched: BetaSchedule, k: usize) -> Self {
        assert!(k >= 2, "a ladder needs at least two rungs, got {k}");
        let betas: Vec<f64> = (0..k).map(|j| sched.beta_at(j, k)).collect();
        assert!(
            betas.windows(2).all(|w| w[1] > w[0]),
            "schedule must be strictly increasing to form a ladder"
        );
        Self { betas }
    }

    /// Number of rungs (replicas).
    pub fn len(&self) -> usize {
        self.betas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.betas.is_empty()
    }

    /// Hottest rung (smallest β).
    pub fn hottest(&self) -> f64 {
        self.betas[0]
    }

    /// Coldest rung (largest β) — the rung whose marginals answer the
    /// sampling question.
    pub fn coldest(&self) -> f64 {
        *self.betas.last().unwrap()
    }

    /// Partition the ladder into `shards` contiguous rung ranges — the
    /// shard plan of the cross-die tempering coordinator
    /// ([`crate::coordinator::run_sharded_tempering`]). Rung counts are
    /// balanced (sizes differ by at most one, larger shards first), the
    /// ranges are in ladder order, every rung lands in exactly one
    /// range, the first range starts at the hottest rung and the last
    /// ends at the coldest.
    ///
    /// Panics unless `1 ≤ shards ≤ len()`.
    pub fn partition(&self, shards: usize) -> Vec<std::ops::Range<usize>> {
        let k = self.len();
        assert!(
            shards >= 1 && shards <= k,
            "need between 1 and {k} shards for a {k}-rung ladder, got {shards}"
        );
        let base = k / shards;
        let rem = k % shards;
        let mut out = Vec::with_capacity(shards);
        let mut start = 0;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            out.push(start..start + len);
            start += len;
        }
        out
    }

    /// Re-space the interior rungs from measured adjacent-pair swap
    /// acceptance rates (`acceptance.len() == len() − 1`).
    ///
    /// Each gap is assigned a "resistance" ∝ 1/acceptance; new rungs are
    /// placed at equal cumulative resistance, interpolating in ln β.
    /// Endpoints are pinned, ordering is preserved, and a ladder whose
    /// acceptance is already uniform comes back unchanged.
    pub fn adapted(&self, acceptance: &[f64]) -> Self {
        let k = self.betas.len();
        assert_eq!(acceptance.len(), k - 1, "need one acceptance rate per adjacent pair");
        // Clamp so an all-rejected gap pulls hard but not infinitely.
        let resist: Vec<f64> = acceptance.iter().map(|&a| 1.0 / a.clamp(0.02, 1.0)).collect();
        let mut cum = Vec::with_capacity(k);
        cum.push(0.0);
        for &r in &resist {
            cum.push(cum.last().unwrap() + r);
        }
        let total = *cum.last().unwrap();
        let lnb: Vec<f64> = self.betas.iter().map(|b| b.ln()).collect();
        let mut out = Vec::with_capacity(k);
        for j in 0..k {
            let target = total * j as f64 / (k - 1) as f64;
            let gap = cum
                .windows(2)
                .position(|w| target <= w[1] + 1e-12)
                .unwrap_or(k - 2);
            let frac = ((target - cum[gap]) / resist[gap].max(1e-300)).clamp(0.0, 1.0);
            out.push((lnb[gap] + frac * (lnb[gap + 1] - lnb[gap])).exp());
        }
        out[0] = self.betas[0];
        out[k - 1] = self.betas[k - 1];
        Self { betas: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let lin = BetaSchedule::Linear { b0: 0.1, b1: 5.0 };
        assert_eq!(lin.beta(0.0), 0.1);
        assert_eq!(lin.beta(1.0), 5.0);
        let geo = BetaSchedule::Geometric { b0: 0.1, b1: 5.0 };
        assert!((geo.beta(0.0) - 0.1).abs() < 1e-12);
        assert!((geo.beta(1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_is_multiplicative() {
        let geo = BetaSchedule::Geometric { b0: 1.0, b1: 16.0 };
        let r1 = geo.beta(0.25) / geo.beta(0.0);
        let r2 = geo.beta(0.5) / geo.beta(0.25);
        assert!((r1 - r2).abs() < 1e-9);
    }

    #[test]
    fn monotone_increasing() {
        for sched in [
            BetaSchedule::Linear { b0: 0.2, b1: 4.0 },
            BetaSchedule::Geometric { b0: 0.2, b1: 4.0 },
        ] {
            let mut prev = 0.0;
            for k in 0..=10 {
                let b = sched.beta_at(k, 11);
                assert!(b >= prev);
                prev = b;
            }
        }
    }

    #[test]
    fn ladder_geometric_endpoints_and_order() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        assert_eq!(l.len(), 8);
        assert!((l.hottest() - 0.1).abs() < 1e-12);
        assert!((l.coldest() - 4.0).abs() < 1e-12);
        assert!(l.betas.windows(2).all(|w| w[1] > w[0]));
        // geometric: constant ratio between rungs
        let r0 = l.betas[1] / l.betas[0];
        for w in l.betas.windows(2) {
            assert!((w[1] / w[0] - r0).abs() < 1e-9);
        }
    }

    #[test]
    fn ladder_uniform_acceptance_is_a_fixed_point() {
        let l = BetaLadder::geometric(0.2, 3.0, 6);
        let a = l.adapted(&[0.4; 5]);
        for (x, y) in l.betas.iter().zip(&a.betas) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn ladder_adapts_toward_the_bottleneck() {
        // gap 0 rejects everything → rungs must crowd into it
        let l = BetaLadder::geometric(0.5, 2.0, 5);
        let a = l.adapted(&[0.02, 0.9, 0.9, 0.9]);
        let old_gap0 = l.betas[1] - l.betas[0];
        let new_gap0 = a.betas[1] - a.betas[0];
        assert!(new_gap0 < old_gap0, "bottleneck gap should shrink: {old_gap0} → {new_gap0}");
        // endpoints pinned, order preserved
        assert_eq!(a.betas[0], l.betas[0]);
        assert_eq!(a.betas[4], l.betas[4]);
        assert!(a.betas.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn ladder_from_schedule_matches_geometric() {
        let a = BetaLadder::geometric(0.1, 4.0, 7);
        let b = BetaLadder::from_schedule(BetaSchedule::Geometric { b0: 0.1, b1: 4.0 }, 7);
        for (x, y) in a.betas.iter().zip(&b.betas) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    /// Property: a partition covers every rung exactly once, in order,
    /// with the endpoints pinned (first range starts at rung 0, last
    /// ends at the coldest rung) and balanced sizes.
    #[test]
    fn prop_partition_covers_every_rung_once_in_order() {
        crate::util::prop::check("ladder partition", 300, |rng| {
            let k = rng.below(30) + 2;
            let shards = rng.below(k) + 1;
            let ladder = BetaLadder::geometric(0.1, 4.0, k);
            let ranges = ladder.partition(shards);
            assert_eq!(ranges.len(), shards);
            // contiguous, ordered, endpoints pinned
            assert_eq!(ranges[0].start, 0, "first shard must start at the hottest rung");
            assert_eq!(ranges[shards - 1].end, k, "last shard must end at the coldest rung");
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile the ladder");
            }
            // every rung exactly once, every shard non-empty, balanced
            let mut covered = vec![0usize; k];
            for r in &ranges {
                assert!(!r.is_empty(), "empty shard in {ranges:?}");
                assert!(r.len() <= k / shards + 1, "unbalanced shard in {ranges:?}");
                for rung in r.clone() {
                    covered[rung] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "rung covered ≠ once: {covered:?}");
        });
    }

    #[test]
    fn partition_single_shard_is_the_whole_ladder() {
        let l = BetaLadder::geometric(0.1, 4.0, 8);
        assert_eq!(l.partition(1), vec![0..8]);
        assert_eq!(l.partition(8), (0..8).map(|i| i..i + 1).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn partition_rejects_more_shards_than_rungs() {
        BetaLadder::geometric(0.1, 4.0, 4).partition(5);
    }

    #[test]
    fn clamps_out_of_range_progress() {
        let lin = BetaSchedule::Linear { b0: 1.0, b1: 2.0 };
        assert_eq!(lin.beta(-0.5), 1.0);
        assert_eq!(lin.beta(1.5), 2.0);
        assert_eq!(lin.beta_at(0, 1), 2.0);
    }
}
