//! β (inverse-temperature) schedules — the V_temp ramp shapes.

/// An annealing schedule mapping progress ∈ [0, 1] to β.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BetaSchedule {
    /// Fixed β (free-running sampling).
    Constant(f64),
    /// Linear ramp β₀ → β₁.
    Linear { b0: f64, b1: f64 },
    /// Geometric ramp β₀ → β₁ (equal multiplicative steps — the classic
    /// SA choice; matches a linearly-ramped V_temp through the tanh
    /// stage's exponential transconductance).
    Geometric { b0: f64, b1: f64 },
}

impl BetaSchedule {
    /// β at progress t ∈ [0, 1].
    pub fn beta(&self, t: f64) -> f64 {
        let t = t.clamp(0.0, 1.0);
        match *self {
            Self::Constant(b) => b,
            Self::Linear { b0, b1 } => b0 + (b1 - b0) * t,
            Self::Geometric { b0, b1 } => b0 * (b1 / b0).powf(t),
        }
    }

    /// β at step `k` of `n` (progress = k/(n−1)).
    pub fn beta_at(&self, k: usize, n: usize) -> f64 {
        if n <= 1 {
            return self.beta(1.0);
        }
        self.beta(k as f64 / (n - 1) as f64)
    }

    pub fn final_beta(&self) -> f64 {
        self.beta(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        let lin = BetaSchedule::Linear { b0: 0.1, b1: 5.0 };
        assert_eq!(lin.beta(0.0), 0.1);
        assert_eq!(lin.beta(1.0), 5.0);
        let geo = BetaSchedule::Geometric { b0: 0.1, b1: 5.0 };
        assert!((geo.beta(0.0) - 0.1).abs() < 1e-12);
        assert!((geo.beta(1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn geometric_is_multiplicative() {
        let geo = BetaSchedule::Geometric { b0: 1.0, b1: 16.0 };
        let r1 = geo.beta(0.25) / geo.beta(0.0);
        let r2 = geo.beta(0.5) / geo.beta(0.25);
        assert!((r1 - r2).abs() < 1e-9);
    }

    #[test]
    fn monotone_increasing() {
        for sched in [
            BetaSchedule::Linear { b0: 0.2, b1: 4.0 },
            BetaSchedule::Geometric { b0: 0.2, b1: 4.0 },
        ] {
            let mut prev = 0.0;
            for k in 0..=10 {
                let b = sched.beta_at(k, 11);
                assert!(b >= prev);
                prev = b;
            }
        }
    }

    #[test]
    fn clamps_out_of_range_progress() {
        let lin = BetaSchedule::Linear { b0: 1.0, b1: 2.0 };
        assert_eq!(lin.beta(-0.5), 1.0);
        assert_eq!(lin.beta(1.5), 2.0);
        assert_eq!(lin.beta_at(0, 1), 2.0);
    }
}
