//! Time-to-solution (Table 1's comparison metric).
//!
//! TTS(99 %) = t_anneal × ln(1 − 0.99) / ln(1 − p_success): the expected
//! wall-clock to reach the target at 99 % confidence given independent
//! restarts of duration `t_anneal` that each succeed with probability
//! `p_success`.

/// A TTS measurement.
#[derive(Debug, Clone, Copy)]
pub struct TtsEstimate {
    /// Per-restart success probability.
    pub p_success: f64,
    /// Duration of one restart in nanoseconds (simulated chip time).
    pub t_anneal_ns: f64,
    /// TTS(99 %) in nanoseconds (∞ if no restart succeeded).
    pub tts99_ns: f64,
    /// Restarts the estimate is based on.
    pub restarts: usize,
}

/// TTS(99 %) from raw success counts — the tempering-mode entry point,
/// where one "restart" is a whole K-replica tempering run (its duration
/// is [`crate::annealing::TemperingParams::chip_time_ns`]; replicas run
/// concurrently on-die, so K does not multiply the time) rather than a
/// single-replica anneal. Head-to-head numbers against [`tts99`] are
/// directly comparable when the per-replica sweep budgets match.
pub fn tts99_counts(successes: usize, attempts: usize, t_run_ns: f64) -> TtsEstimate {
    let p = successes as f64 / attempts.max(1) as f64;
    tts99(p, t_run_ns, attempts)
}

/// Compute TTS(99 %).
pub fn tts99(p_success: f64, t_anneal_ns: f64, restarts: usize) -> TtsEstimate {
    let tts = if p_success <= 0.0 {
        f64::INFINITY
    } else if p_success >= 1.0 {
        t_anneal_ns
    } else {
        t_anneal_ns * (0.01f64).ln() / (1.0 - p_success).ln()
    };
    TtsEstimate { p_success, t_anneal_ns, tts99_ns: tts, restarts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn certain_success_is_one_anneal() {
        let t = tts99(1.0, 500.0, 10);
        assert_eq!(t.tts99_ns, 500.0);
    }

    #[test]
    fn never_succeeds_is_infinite() {
        assert!(tts99(0.0, 500.0, 10).tts99_ns.is_infinite());
    }

    #[test]
    fn half_success_needs_log_restarts() {
        // p = 0.5 → need log2(100) ≈ 6.64 restarts
        let t = tts99(0.5, 100.0, 10);
        assert!((t.tts99_ns - 100.0 * (0.01f64).ln() / (0.5f64).ln()).abs() < 1e-9);
        assert!((t.tts99_ns / 100.0 - 6.6438).abs() < 1e-3);
    }

    #[test]
    fn monotone_in_success_probability() {
        let lo = tts99(0.1, 100.0, 1).tts99_ns;
        let hi = tts99(0.9, 100.0, 1).tts99_ns;
        assert!(hi < lo);
    }

    #[test]
    fn counts_agree_with_probability_form() {
        let a = tts99_counts(3, 12, 400.0);
        let b = tts99(0.25, 400.0, 12);
        assert_eq!(a.tts99_ns, b.tts99_ns);
        assert_eq!(a.p_success, 0.25);
        assert_eq!(a.restarts, 12);
        // zero attempts must not divide by zero
        assert!(tts99_counts(0, 0, 100.0).tts99_ns.is_infinite());
    }
}
