//! Simulated annealing on the p-bit array (Fig 9a), replica-exchange
//! (parallel tempering) sampling, and time-to-solution accounting
//! (Table 1).
//!
//! On silicon the anneal is a V_temp voltage ramp; here the schedule
//! drives the β knob of any [`crate::sampler::Sampler`], and the TTS
//! estimator converts measured success probabilities into the
//! TTS(99 %) figure Table 1 compares across chips.
//!
//! Two sampling modes share this module:
//!
//! * [`anneal`] — one β ramp over every chain (the paper's Fig 9a
//!   experiment; on silicon, the V_temp ramp).
//! * [`temper`] — K replicas pinned to a [`BetaLadder`], exchanging
//!   temperatures by Metropolis swap moves every few sweeps. The
//!   standard algorithmic lever for frustrated instances where a single
//!   annealed replica stalls.
//!
//! The ladder itself is a tunable: [`tune_ladder`] runs the
//! round-trip-flux feedback loop (measure the up-mover profile,
//! re-space, auto-size K) and returns a [`TunedLadder`] for reuse
//! across jobs — `docs/TUNING.md` is the practitioner guide.

mod sa;
mod schedule;
mod tempering;
mod tts;
mod tuner;

pub use sa::{anneal, AnnealParams};
pub use schedule::{BetaLadder, BetaSchedule};
pub use tempering::{
    temper, temper_observed, temper_pipelined, temper_pipelined_observed, LadderTuning,
    PipelinedCore, TemperingCore, TemperingParams, TemperingRun,
};
pub(crate) use tempering::EnergyReadback;
pub use tts::{tts99, tts99_counts, TtsEstimate};
pub use tuner::{tune_ladder, TuneAction, TuneIteration, TunedLadder, TunerParams};
