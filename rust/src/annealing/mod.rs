//! Simulated annealing on the p-bit array (Fig 9a) and time-to-solution
//! accounting (Table 1).
//!
//! On silicon the anneal is a V_temp voltage ramp; here the schedule
//! drives the β knob of any [`crate::sampler::Sampler`], and the TTS
//! estimator converts measured success probabilities into the
//! TTS(99 %) figure Table 1 compares across chips.

mod sa;
mod schedule;
mod tts;

pub use sa::{anneal, AnnealParams};
pub use schedule::BetaSchedule;
pub use tts::{tts99, TtsEstimate};
