//! `pchip` — the coordinator CLI.
//!
//! ```text
//! pchip info                         chip facts + artifact status
//! pchip train  [--gate and|or|xor|nand|nor|adder] [--dies N] [--pcd]
//!              [--tempered-negative] [--pipeline] [--elastic]
//!              [--epochs N] [--lr X] [--fault-plan FILE]
//!              [--checkpoint-out FILE] [--resume FILE]
//!              [--listen HOST:PORT] …
//! pchip anneal [--seed S] [--steps N] [--b0 X] [--b1 X]
//! pchip temper [--seed S] [--replicas K] [--rounds N] [--b0 X] [--b1 X]
//!              [--shards N] [--pipeline] [--elastic] [--fanout N]
//!              [--fault-plan FILE] [--net-plan FILE] [--barrier-timeout-ms T]
//!              [--tune off|acceptance|flux] [--adapt-every N]
//!              [--listen HOST:PORT]
//! pchip worker --connect HOST:PORT [--protocol temper|train] [--seat K]
//!              (+ the same problem flags as the listening coordinator)
//! pchip tune-ladder [--seed S] [--replicas K] [--b0 X] [--b1 X]
//!              [--iters N] [--floor A] [--ceiling A] [--min-k K] [--max-k K]
//! pchip maxcut [--native-keep P | --clique-n N]
//! pchip sweep  [--pbits N] [--points N]           (Fig 8a bias sweep)
//! pchip tts    [--restarts N]                     (Table 1)
//! pchip serve  [--jobs N] [--chips K] [--engine sw|xla]   E2E demo load
//! pchip report FILE                  render a --trace-out JSONL trace
//! ```
//!
//! All subcommands accept `--config path.toml` and `--engine sw|xla` and
//! write CSV series into `results/`. `train` and `temper` also accept
//! `--trace-out FILE` / `--trace-perfetto FILE`, which enable the
//! telemetry plane (see `docs/OBSERVABILITY.md`) for the run and export
//! the recorded stream; `PCHIP_LOG=debug|info|warn` sets the stderr
//! log level and `PCHIP_TELEMETRY=1` enables recording without export.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use pchip::annealing::{AnnealParams, BetaSchedule};
use pchip::chimera::Topology;
use pchip::config::Config;
use pchip::coordinator::{ChipArrayServer, EngineKind, JobRequest, JobResult};
use pchip::experiments as exp;
use pchip::learning::{dataset, CdParams, Hw, TrainableChip};
use pchip::problems::maxcut::Graph;
use pchip::runtime::{ArtifactSet, Runtime};
use pchip::sampler::XlaSampler;

/// Minimal flag parser: `--key value` pairs after the subcommand;
/// a `--key` followed by another flag (or the end of the line) is a
/// bare boolean flag (`--pcd`, `--tempered-negative`).
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got `{}`", argv[i]))?;
            match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(k.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    // bare flag: stored empty so value-taking flags can
                    // still diagnose a forgotten value (`path_of`)
                    flags.insert(k.to_string(), String::new());
                    i += 1;
                }
            }
        }
        Ok(Self { flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{key}: `{v}`")),
        }
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("" | "true" | "1" | "yes"))
    }

    /// A flag that must carry a file path when present.
    fn path_of(&self, key: &str) -> Result<Option<&str>> {
        match self.flags.get(key).map(String::as_str) {
            None => Ok(None),
            Some("") => Err(anyhow!("--{key} needs a file path")),
            Some(p) => Ok(Some(p)),
        }
    }

    /// A flag that must carry a value when present (`--listen HOST:PORT`).
    fn value_of(&self, key: &str) -> Result<Option<&str>> {
        match self.flags.get(key).map(String::as_str) {
            None => Ok(None),
            Some("") => Err(anyhow!("--{key} needs a value")),
            Some(v) => Ok(Some(v)),
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    match args.flags.get("config") {
        Some(p) => Config::load(std::path::Path::new(p)),
        None => Ok(Config::default()),
    }
}

/// `--fault-plan FILE`: a deterministic fault-injection schedule (JSON
/// from [`pchip::util::fault::FaultPlan::to_json`]) wired under every
/// software die. `None` when the flag is absent.
fn fault_plan(args: &Args) -> Result<Option<pchip::util::fault::FaultPlan>> {
    match args.path_of("fault-plan")? {
        None => Ok(None),
        Some(p) => {
            let text =
                std::fs::read_to_string(p).map_err(|e| anyhow!("--fault-plan {p}: {e}"))?;
            let v = pchip::util::json::Json::parse(&text)?;
            Ok(Some(pchip::util::fault::FaultPlan::from_json(&v)?))
        }
    }
}

/// `--net-plan FILE`: a deterministic per-link impairment schedule
/// (JSON from [`pchip::transport::NetPlan::to_json`], e.g. a plan the
/// transport-sim suite dumped to `target/net-failing-plan.json`) laid
/// over the coordinator↔die lanes. `None` when the flag is absent.
fn net_plan(args: &Args) -> Result<Option<pchip::transport::NetPlan>> {
    match args.path_of("net-plan")? {
        None => Ok(None),
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("--net-plan {p}: {e}"))?;
            let v = pchip::util::json::Json::parse(&text)?;
            Ok(Some(pchip::transport::NetPlan::from_json(&v)?))
        }
    }
}

/// Socket-transport knobs shared by `--listen` coordinators and `pchip
/// worker`: `--heartbeat-ms`, `--idle-timeout-ms` and `--max-reconnects`
/// override the [`pchip::transport::SocketConfig`] defaults.
fn socket_config_from_args(args: &Args) -> Result<pchip::transport::SocketConfig> {
    let d = pchip::transport::SocketConfig::default();
    Ok(pchip::transport::SocketConfig {
        heartbeat: std::time::Duration::from_millis(
            args.get("heartbeat-ms", d.heartbeat.as_millis() as u64)?,
        ),
        idle_timeout: std::time::Duration::from_millis(
            args.get("idle-timeout-ms", d.idle_timeout.as_millis() as u64)?,
        ),
        max_reconnects: args.get("max-reconnects", d.max_reconnects)?,
        ..d
    })
}

/// Per-link delivery + session counters of a socket (or simulated) gang
/// → the leveled logger (stderr at info), one line per link.
fn print_link_sessions(links: &[pchip::metrics::LinkStats]) {
    for (k, l) in links.iter().enumerate() {
        pchip::log_info!(
            "link {k}: down {}/{} delivered ({} dropped), up {}/{} ({} dropped); sessions: \
             {} connect(s), {} reconnect(s), {} reject(s), {} heartbeat(s), {} corrupt",
            l.down.delivered,
            l.down.sent,
            l.down.dropped,
            l.up.delivered,
            l.up.sent,
            l.up.dropped,
            l.connects,
            l.reconnects,
            l.rejects,
            l.heartbeats,
            l.corrupt
        );
    }
}

/// Per-die membership-change log of an elastic gang run → the leveled
/// logger (stderr at warn), one line per event, so scripts can grep
/// which die died or rejoined when.
fn print_membership(events: &[pchip::metrics::MembershipEvent]) {
    for e in events {
        pchip::log_warn!("membership: round {:>4}  die {}  {:?}", e.round, e.die, e.change);
    }
}

/// `--trace-out FILE` (JSONL event stream) / `--trace-perfetto FILE`
/// (Chrome `trace_event` JSON). Either flag turns telemetry recording
/// on for the whole run.
struct TraceArgs {
    jsonl: Option<String>,
    perfetto: Option<String>,
}

fn trace_args(args: &Args) -> Result<TraceArgs> {
    let t = TraceArgs {
        jsonl: args.path_of("trace-out")?.map(str::to_string),
        perfetto: args.path_of("trace-perfetto")?.map(str::to_string),
    };
    if t.jsonl.is_some() || t.perfetto.is_some() {
        pchip::telemetry::set_enabled(true);
    }
    Ok(t)
}

impl TraceArgs {
    /// Write the requested exports — `summary` becomes the JSONL
    /// `summary` record, `extra` rows (e.g. the energy trace) are
    /// appended to the stream — and say where they went.
    fn export(
        &self,
        summary: Option<&pchip::telemetry::RunTelemetry>,
        extra: &[pchip::util::json::Json],
    ) -> Result<()> {
        if let Some(p) = &self.jsonl {
            pchip::telemetry::export::write_jsonl(std::path::Path::new(p), summary, extra)?;
            println!("  telemetry stream → {p} (read with `pchip report {p}`)");
        }
        if let Some(p) = &self.perfetto {
            pchip::telemetry::export::write_perfetto(std::path::Path::new(p))?;
            println!("  perfetto trace → {p} (open in ui.perfetto.dev)");
        }
        Ok(())
    }

    /// The cumulative run summary when recording is on (the paths that
    /// don't get a per-run [`pchip::telemetry::RunTelemetry`] attached).
    fn cumulative_summary(&self) -> Option<pchip::telemetry::RunTelemetry> {
        pchip::telemetry::enabled().then(pchip::telemetry::RunTelemetry::capture_cumulative)
    }
}

fn main() -> Result<()> {
    pchip::telemetry::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    if cmd == "report" {
        return cmd_report(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "anneal" => cmd_anneal(&args),
        "temper" => cmd_temper(&args),
        "worker" => cmd_worker(&args),
        "tune-ladder" => cmd_tune_ladder(&args),
        "maxcut" => cmd_maxcut(&args),
        "sweep" => cmd_sweep(&args),
        "tts" => cmd_tts(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `pchip help`)"),
    }
}

/// `pchip report FILE` — render the summary/counter/histogram tables of
/// a JSONL trace written by `--trace-out`.
fn cmd_report(argv: &[String]) -> Result<()> {
    let Some(path) = argv.first() else {
        bail!("usage: pchip report FILE (a .jsonl trace from --trace-out)");
    };
    let text = pchip::telemetry::export::report_from_jsonl(std::path::Path::new(path))?;
    print!("{text}");
    Ok(())
}

fn print_help() {
    println!(
        "pchip — 440-spin CMOS p-bit chip reproduction\n\n\
         subcommands:\n  \
         info    chip facts + artifact status\n  \
         train   hardware-aware CD learning of a gate (Figs 7, 8b)\n  \
         \u{20}       (--dies N fans the epoch across N dies through the\n  \
         \u{20}        coordinator; --pcd keeps persistent negative chains;\n  \
         \u{20}        --tempered-negative mixes the model via a β-ladder;\n  \
         \u{20}        --pipeline streams phases into the all-reduce and\n  \
         \u{20}        overlaps evaluations with the next epoch;\n  \
         \u{20}        --elastic retries epochs over surviving dies when\n  \
         \u{20}        one fails mid-run, readmitting it when it recovers)\n  \
         anneal  SK spin-glass annealing (Fig 9a)\n  \
         temper  replica-exchange sampling vs annealing, head-to-head\n  \
         \u{20}       (--shards N shards the ladder across N software dies;\n  \
         \u{20}        --pipeline overlaps sweeps with swap/readback, 1-phase lag;\n  \
         \u{20}        --elastic re-partitions the ladder onto the surviving\n  \
         \u{20}        dies when one is lost mid-run;\n  \
         \u{20}        --net-plan FILE runs the gang over the network simulator\n  \
         \u{20}        with that scripted per-link impairment schedule;\n  \
         \u{20}        --listen HOST:PORT seats the gang over TCP — each seat\n  \
         \u{20}        is a remote `pchip worker --connect` process;\n  \
         \u{20}        --tune flux re-spaces the ladder in-run by round-trip flux)\n  \
         worker  one remote die: dial a --listen'ing temper/train\n  \
         \u{20}       coordinator (--connect HOST:PORT --protocol temper|train\n  \
         \u{20}        --seat K, plus the coordinator's problem flags)\n  \
         tune-ladder  feedback-optimize a β-ladder (round-trip flux, auto-K)\n  \
         maxcut  Max-Cut optimization (Fig 9b)\n  \
         sweep   bias-sweep variability (Fig 8a)\n  \
         tts     time-to-solution measurement (Table 1)\n  \
         serve   chip-array serving demo (batched sampling jobs)\n  \
         report  render a JSONL telemetry trace written by --trace-out\n\n\
         common flags: --config FILE --engine sw|xla --seed N\n\
         telemetry: --trace-out FILE --trace-perfetto FILE (train, temper)\n\
         \u{20}          PCHIP_LOG=debug|info|warn   PCHIP_TELEMETRY=1"
    );
}

/// Build a trainable chip for the chosen engine and run `f` on it.
fn with_chip<F, R>(args: &Args, cfg: &Config, batch: usize, f: F) -> Result<R>
where
    F: FnOnce(&mut dyn ErasedChip) -> Result<R>,
{
    let seed: u64 = args.get("seed", cfg.server.seed)?;
    match args.str_or("engine", "sw").as_str() {
        "sw" => {
            let mut chip = exp::software_chip(seed, cfg.mismatch, batch);
            f(&mut chip)
        }
        "xla" => {
            let rt = Runtime::cpu()?;
            let set = ArtifactSet::load_some(
                &rt,
                &cfg.artifacts_dir(),
                &["gibbs_b32", "gibbs_b8", "gibbs_b1"],
            )?;
            let engine = XlaSampler::new(&set, batch, seed)?;
            let topo = Topology::new();
            let personality = pchip::analog::Personality::sample(&topo, seed, cfg.mismatch);
            let mut chip = Hw::new(engine, personality);
            f(&mut chip)
        }
        other => bail!("unknown engine `{other}` (sw|xla)"),
    }
}

/// Object-safe alias over TrainableChip (the CLI doesn't need generics).
trait ErasedChip: TrainableChip {}
impl<T: TrainableChip> ErasedChip for T {}

impl TrainableChip for &mut dyn ErasedChip {
    fn program_codes(&mut self, w: &pchip::analog::ProgrammedWeights) -> Result<()> {
        (**self).program_codes(w)
    }
}

impl pchip::sampler::Sampler for &mut dyn ErasedChip {
    fn load(&mut self, folded: &pchip::analog::Folded) {
        (**self).load(folded)
    }
    fn set_beta(&mut self, beta: f32) {
        (**self).set_beta(beta)
    }
    fn set_betas(&mut self, betas: &[f32]) -> Result<()> {
        (**self).set_betas(betas)
    }
    fn set_states(&mut self, states: &[Vec<i8>]) -> Result<()> {
        (**self).set_states(states)
    }
    fn set_clamps(&mut self, clamps: &[(usize, i8)]) {
        (**self).set_clamps(clamps)
    }
    fn batch(&self) -> usize {
        (**self).batch()
    }
    fn sweeps(&mut self, n: usize) -> Result<()> {
        (**self).sweeps(n)
    }
    fn states(&self) -> Vec<Vec<i8>> {
        (**self).states()
    }
    fn for_each_state(&self, f: &mut dyn FnMut(usize, &[i8])) {
        (**self).for_each_state(f)
    }
    fn track_energies(&mut self, ledger: &pchip::problems::EnergyLedger) -> Result<()> {
        (**self).track_energies(ledger)
    }
    fn energies(&mut self) -> Result<Vec<f64>> {
        (**self).energies()
    }
    fn randomize(&mut self, seed: u64) {
        (**self).randomize(seed)
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!("pchip: 440-spin Chimera p-bit chip (7x8 cells, one dead)");
    let topo = Topology::new();
    println!("  spins: {}   couplers: {}", pchip::N_SPINS, topo.edges.len());
    println!("  sample time: {} ns   master clock: 200 MHz", pchip::chip::SAMPLE_TIME_NS);
    println!("  mismatch corner: {:?}", cfg.mismatch);
    let dir = cfg.artifacts_dir();
    match pchip::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "  artifacts ({}): {} entries, N_PAD={}",
                dir.display(),
                m.entries.len(),
                m.meta.n_pad
            );
        }
        Err(_) => println!("  artifacts: NOT BUILT (run `make artifacts`)"),
    }
    for (k, v) in exp::table1::spec_row() {
        println!("  {k}: {v}");
    }
    Ok(())
}

/// Pick a gate layout + dataset by name.
fn gate_by_name(gate: &str) -> Result<(pchip::chimera::GateLayout, dataset::Dataset)> {
    Ok(match gate {
        "and" => (pchip::chimera::and_gate_layout(0, 0), dataset::and_gate()),
        "or" => (pchip::chimera::and_gate_layout(0, 0), dataset::or_gate()),
        "xor" => (pchip::chimera::and_gate_layout(0, 0), dataset::xor_gate()),
        "nand" => (pchip::chimera::and_gate_layout(0, 0), dataset::nand_gate()),
        "nor" => (pchip::chimera::and_gate_layout(0, 0), dataset::nor_gate()),
        "adder" => (pchip::chimera::full_adder_layout(0, 1), dataset::full_adder()),
        g => bail!("unknown gate `{g}` (and|or|xor|nand|nor|adder)"),
    })
}

/// The [`pchip::learning::TrainParams`] a `pchip train` flag set
/// describes, plus the gate name for reporting. Shared with
/// `pchip worker --protocol train`, which must rebuild exactly the run
/// its coordinator is serving from the same flags.
fn train_params_from_args(args: &Args) -> Result<(String, pchip::learning::TrainParams)> {
    use pchip::annealing::LadderTuning;
    use pchip::learning::{TemperedNegative, TrainParams};

    let gate = args.str_or("gate", "and");
    let (layout, data) = gate_by_name(&gate)?;
    let epochs: usize = args.get("epochs", 150)?;
    let mut cd = CdParams { epochs, ..CdParams::default() };
    cd.lr = args.get("lr", cd.lr)?;
    cd.beta = args.get("beta", cd.beta)?;
    cd.k_sweeps = args.get("k-sweeps", cd.k_sweeps)?;
    cd.samples_per_pattern = args.get("samples-per-pattern", cd.samples_per_pattern)?;
    let mut params = TrainParams::new(layout, data, cd);
    params.dies = args.get("dies", 1)?;
    params.pcd = args.flag("pcd");
    params.pipeline = args.flag("pipeline");
    params.elastic = args.flag("elastic");
    params.eval_every = args.get("eval-every", 5)?;
    params.eval_samples = args.get("eval-samples", 4000)?;
    params.seed = args.get("seed", 7u64)?;
    if args.flag("tempered-negative") {
        params.tempered = Some(TemperedNegative {
            rungs: args.get("neg-rungs", 6)?,
            beta_hot: args.get("neg-beta-hot", 0.5)?,
            sweeps_per_round: args.get("neg-sweeps-per-round", 2)?,
            adapt_every: args.get("neg-adapt-every", 0)?,
            tuning: match args.str_or("neg-tune", "off").as_str() {
                "off" => LadderTuning::Off,
                "acceptance" => LadderTuning::Acceptance,
                "flux" => LadderTuning::RoundTripFlux,
                other => bail!("unknown --neg-tune `{other}` (off|acceptance|flux)"),
            },
            ..Default::default()
        });
    }
    Ok((gate, params))
}

fn cmd_train(args: &Args) -> Result<()> {
    use pchip::learning::TrainCheckpoint;

    let mut cfg = load_config(args)?;
    let trace = trace_args(args)?; // before the run so recording covers it
    let (gate, params) = train_params_from_args(args)?;
    let epochs = params.cd.epochs;
    let dies = params.dies;
    let resume = match args.path_of("resume")? {
        Some(p) => Some(TrainCheckpoint::load(std::path::Path::new(p))?),
        None => None,
    };

    // --listen HOST:PORT: the gang's dies are remote `pchip worker`
    // processes dialing in over TCP instead of in-process threads.
    if let Some(addr) = args.value_of("listen")? {
        let addr = addr.to_string();
        return train_over_sockets(args, &addr, &trace, &gate, params, resume);
    }

    // the array IS the gang: one die per shard, each with its own
    // personality (cfg.server.seed + k), every phase through silicon
    cfg.server.chips = dies;
    let engine = match (args.str_or("engine", "sw").as_str(), fault_plan(args)?) {
        ("sw", None) => EngineKind::Software,
        ("sw", Some(plan)) => EngineKind::SoftwareFaulty { batch: 32, plan },
        ("xla", None) => EngineKind::Xla { artifacts_dir: cfg.artifacts_dir() },
        ("xla", Some(_)) => bail!("--fault-plan needs the sw engine"),
        (other, _) => bail!("unknown engine `{other}` (sw|xla)"),
    };
    let srv = ChipArrayServer::start(&cfg, engine)?;
    let mode = match (&resume, params.pcd, params.tempered.is_some()) {
        (Some(_), _, _) => "resumed",
        (None, true, true) => "PCD + tempered negative",
        (None, true, false) => "PCD",
        (None, false, true) => "tempered negative",
        (None, false, false) => "CD-k",
    };
    println!(
        "training {gate} across {dies} die(s) [{mode}] — {} epochs through the coordinator",
        epochs
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let request = match resume {
        Some(checkpoint) => {
            JobRequest::TrainEpoch { params, checkpoint, epochs, progress: Some(tx) }
        }
        None => JobRequest::Train { params, progress: Some(tx) },
    };
    let ticket = srv.submit(request)?;
    println!("{:>6} {:>10} {:>10} {:>12}", "epoch", "KL", "corr_gap", "valid_mass");
    for s in rx {
        println!("{:>6} {:>10.4} {:>10.4} {:>12.3}", s.epoch, s.kl, s.corr_gap, s.valid_mass);
    }
    match ticket.wait() {
        JobResult::Trained {
            stats,
            checkpoint,
            final_kl,
            final_valid_mass,
            dies,
            membership,
            ..
        } => {
            print_membership(&membership);
            println!(
                "gate {gate}: final KL {final_kl:.4}, valid mass {final_valid_mass:.3} \
                 (dies {dies:?}{})",
                if membership.is_empty() { "" } else { ", gang shrank/regrew — see stderr" }
            );
            let name = format!("train_{gate}");
            let rows: Vec<Vec<f64>> = stats
                .iter()
                .map(|e| vec![e.epoch as f64, e.kl, e.corr_gap, e.valid_mass])
                .collect();
            pchip::util::bench::write_csv(&name, "epoch,kl,corr_gap,valid_mass", &rows)?;
            println!("  per-epoch series → results/{name}.csv");
            if let Some(path) = args.path_of("checkpoint-out")? {
                checkpoint.save(std::path::Path::new(path))?;
                println!("  checkpoint → {path} (resume with --resume {path})");
            }
            // the last epoch's stamped rollup is the run summary; fall
            // back to the cumulative capture if evaluation never ran
            let summary = stats
                .last()
                .and_then(|s| s.telemetry.clone())
                .or_else(|| trace.cumulative_summary());
            trace.export(summary.as_ref(), &[])?;
            Ok(())
        }
        JobResult::Failed(msg) => bail!("training failed: {msg}"),
        other => bail!("unexpected result {other:?}"),
    }
}

/// `pchip train --listen HOST:PORT`: drive the epoch protocol over a
/// TCP-seated gang. Every one of the run's `--dies` seats must be
/// claimed by a remote `pchip worker --connect HOST:PORT --protocol
/// train --seat K` process started from the same flag set (the worker
/// rebuilds its die and chain seeds from the flags, so a mismatched
/// flag set means a mismatched run, not an error).
fn train_over_sockets(
    args: &Args,
    addr: &str,
    trace: &TraceArgs,
    gate: &str,
    params: pchip::learning::TrainParams,
    resume: Option<pchip::learning::TrainCheckpoint>,
) -> Result<()> {
    use pchip::learning::{run_training_net, TrainCmd, TrainMsg};
    use pchip::transport::SocketTransport;

    anyhow::ensure!(
        fault_plan(args)?.is_none(),
        "--fault-plan injects faults under the in-process array; a socket gang's faults \
         are real worker deaths (kill the worker instead)"
    );
    anyhow::ensure!(
        args.str_or("engine", "sw") == "sw",
        "--listen seats remote software workers; --engine does not apply"
    );
    let epochs = params.cd.epochs;
    let sock = socket_config_from_args(args)?;
    let net = SocketTransport::<TrainCmd, TrainMsg>::listen(addr, params.dies, sock)?;
    println!(
        "listening on {} for {} training worker(s) — seat each with \
         `pchip worker --connect {} --protocol train --seat K …` (same problem flags)",
        net.local_addr(),
        params.dies,
        net.local_addr()
    );
    let dies = params.dies;
    println!("{:>6} {:>10} {:>10} {:>12}", "epoch", "KL", "corr_gap", "valid_mass");
    let (run, links) = run_training_net(&params, resume.as_ref(), epochs, &net, |s| {
        println!("{:>6} {:>10.4} {:>10.4} {:>12.3}", s.epoch, s.kl, s.corr_gap, s.valid_mass);
    })?;
    print_membership(&run.membership);
    println!(
        "gate {gate}: final KL {:.4}, valid mass {:.3} (socket gang of {dies}{})",
        run.final_kl,
        run.final_valid_mass,
        if run.membership.is_empty() { "" } else { ", gang shrank/regrew — see stderr" }
    );
    print_link_sessions(&links);
    let name = format!("train_{gate}");
    let rows: Vec<Vec<f64>> = run
        .stats
        .iter()
        .map(|e| vec![e.epoch as f64, e.kl, e.corr_gap, e.valid_mass])
        .collect();
    pchip::util::bench::write_csv(&name, "epoch,kl,corr_gap,valid_mass", &rows)?;
    println!("  per-epoch series → results/{name}.csv");
    if let Some(path) = args.path_of("checkpoint-out")? {
        run.checkpoint.save(std::path::Path::new(path))?;
        println!("  checkpoint → {path} (resume with --resume {path})");
    }
    let summary = run.telemetry.clone().or_else(|| trace.cumulative_summary());
    trace.export(summary.as_ref(), &[])?;
    Ok(())
}

fn cmd_anneal(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let params = AnnealParams {
        schedule: BetaSchedule::Geometric { b0: args.get("b0", 0.08)?, b1: args.get("b1", 4.0)? },
        steps: args.get("steps", 96)?,
        sweeps_per_step: args.get("sweeps-per-step", 8)?,
        record_every: 1,
    };
    let seed = args.get("seed", 1u64)?;
    let report = with_chip(args, &cfg, 8, |mut chip| {
        exp::fig9a_sk_anneal(&mut chip, seed, &params, Some("fig9a_sk"))
    })?;
    println!(
        "SK anneal (seed {seed}): best energy {:.0} (bound {:.0})",
        report.best_energy, report.energy_lower_bound
    );
    println!("  trace → results/fig9a_sk.csv");
    Ok(())
}

fn cmd_temper(args: &Args) -> Result<()> {
    use pchip::annealing::{BetaLadder, LadderTuning, TemperingParams};
    let cfg = load_config(args)?;
    let trace = trace_args(args)?; // before the run so recording covers it
    let b0: f64 = args.get("b0", 0.08)?;
    let b1: f64 = args.get("b1", 4.0)?;
    let replicas: usize = args.get("replicas", 8)?;
    anyhow::ensure!(replicas >= 2, "--replicas must be at least 2, got {replicas}");
    anyhow::ensure!(b0 > 0.0 && b1 > b0, "need 0 < --b0 < --b1, got {b0}..{b1}");
    let rounds: usize = args.get("rounds", 96)?;
    let sweeps_per_round: usize = args.get("sweeps-per-round", 8)?;
    let seed = args.get("seed", 1u64)?;
    let tuning = match args.str_or("tune", "acceptance").as_str() {
        "off" => LadderTuning::Off,
        "acceptance" => LadderTuning::Acceptance,
        "flux" => LadderTuning::RoundTripFlux,
        other => bail!("unknown --tune `{other}` (off|acceptance|flux)"),
    };
    // --tune flux turns in-run adaptation on by default; the historical
    // acceptance signal still waits for an explicit --adapt-every
    let adapt_default = if tuning == LadderTuning::RoundTripFlux { 16 } else { 0 };
    let anneal_params = AnnealParams {
        schedule: BetaSchedule::Geometric { b0, b1 },
        steps: rounds,
        sweeps_per_step: sweeps_per_round,
        record_every: 1,
    };
    let temper_params = TemperingParams {
        ladder: BetaLadder::geometric(b0, b1, replicas),
        sweeps_per_round,
        rounds,
        adapt_every: args.get("adapt-every", adapt_default)?,
        tuning,
        record_every: 1,
        seed: args.get("swap-seed", 0x9A77u64)?,
    };

    // --fanout N: N independent runs of this instance through the
    // chip-array server, one die each, keeping the best. Per-die
    // failures print to stderr and fail the command — a die that errors
    // is an array-health event the caller must see, not a statistic the
    // winning run gets to hide.
    let fanout: usize = args.get("fanout", 0)?;
    if fanout > 0 {
        anyhow::ensure!(
            args.str_or("engine", "sw") == "sw",
            "--fanout needs the sw engine (per-chain β)"
        );
        let mut scfg = cfg.clone();
        scfg.server.chips = fanout;
        let engine = match fault_plan(args)? {
            Some(plan) => EngineKind::SoftwareFaulty { batch: replicas.max(8), plan },
            None => EngineKind::SoftwareBatch { batch: replicas.max(8) },
        };
        let srv = ChipArrayServer::start(&scfg, engine)?;
        let topo = Topology::new();
        let h = srv.register_problem(pchip::problems::sk::chimera_pm_j(&topo, seed))?;
        let report = srv.run_tempering_fanout(h, &temper_params, fanout)?;
        for f in &report.failures {
            pchip::log_warn!("die failure: {f}");
        }
        match &report.best {
            JobResult::Tempered { best_energy, .. } => {
                println!("fanout over {fanout} die(s): best energy {best_energy:.0}");
            }
            JobResult::Failed(msg) => pchip::log_warn!("no run succeeded: {msg}"),
            other => bail!("unexpected result {other:?}"),
        }
        // export before the failure bail so a partly-failed fanout still
        // leaves its trace behind
        trace.export(trace.cumulative_summary().as_ref(), &[])?;
        if !report.failures.is_empty() {
            bail!(
                "{} of {} tempering runs failed (per-die diagnostics above)",
                report.failures.len(),
                report.runs
            );
        }
        return Ok(());
    }

    // --listen HOST:PORT: serve the sharded gang over TCP — every seat
    // is a remote `pchip worker --connect … --protocol temper` process
    // rebuilding its die from this same flag set (--seed/--replicas/
    // --shards/--b0/--b1). This process is the coordinator only: no
    // local die, no single-die head-to-head.
    if let Some(addr) = args.value_of("listen")? {
        anyhow::ensure!(
            net_plan(args)?.is_none() && fault_plan(args)?.is_none(),
            "--listen drives real sockets; --net-plan/--fault-plan script the in-process \
             harnesses — pick one per run"
        );
        let shards: usize = args.get("shards", 1)?;
        anyhow::ensure!(
            shards <= replicas,
            "--shards {shards} cannot exceed --replicas {replicas}"
        );
        let sharded_params = pchip::coordinator::ShardedTemperingParams {
            base: temper_params.clone(),
            shards,
            barrier_timeout: std::time::Duration::from_millis(
                args.get("barrier-timeout-ms", 30_000u64)?,
            ),
            pipeline: args.flag("pipeline"),
            elastic: args.flag("elastic"),
        };
        let topo = Topology::new();
        let problem = pchip::problems::sk::chimera_pm_j(&topo, seed);
        // the code→logical β scale is a pure function of the problem's
        // lowering; every worker programs the same codes and lands on
        // the same value, so the coordinator needs no die to know it
        let (_, _, _, scale) = problem.to_codes(&topo)?;
        use pchip::coordinator::{ShardCmd, ShardMsg};
        let sock = socket_config_from_args(args)?;
        let net =
            pchip::transport::SocketTransport::<ShardCmd, ShardMsg>::listen(addr, shards, sock)?;
        println!(
            "listening on {} for {shards} tempering worker(s) — seat each with \
             `pchip worker --connect {} --protocol temper --seat K …` (same problem flags)",
            net.local_addr(),
            net.local_addr()
        );
        let r = pchip::coordinator::run_sharded_tempering_net(
            &sharded_params,
            scale,
            &net,
            |_, _, _| {},
        )?;
        print_membership(&r.membership);
        println!(
            "sharded over TCP: best {:.0} ({} shard(s) at the end{})",
            r.run.best_energy,
            r.shards,
            if r.membership.is_empty() { "" } else { ", membership log on stderr" }
        );
        print_link_sessions(&r.net);
        trace.export(r.telemetry.as_ref(), &r.run.trace.jsonl_rows())?;
        return Ok(());
    }

    let report = with_chip(args, &cfg, replicas.max(8), |mut chip| {
        exp::fig9a_sk_temper_vs_anneal(
            &mut chip,
            seed,
            &anneal_params,
            &temper_params,
            Some("fig9a_temper"),
        )
    })?;
    println!(
        "SK seed {seed}: anneal best {:.0} | tempering best {:.0} (bound {:.0})",
        report.anneal.best_energy, report.temper.best_energy, report.anneal.energy_lower_bound
    );
    let fmt = |s: Option<u64>| s.map(|v| v.to_string()).unwrap_or_else(|| "never".into());
    println!(
        "  sweeps to reach anneal-best {:.0}:  anneal {}  tempering {}",
        report.target_energy,
        fmt(report.anneal_sweeps_to_target),
        fmt(report.temper_sweeps_to_target)
    );
    println!(
        "  swaps: mean acceptance {:.2}, bottleneck {:.2}, round trips {}",
        report.temper.swaps.mean_acceptance(),
        report.temper.swaps.min_acceptance(),
        report.temper.swaps.round_trips
    );
    let f = report.temper.flux.f_profile();
    println!(
        "  flux: f(β) {:?}  ({:.4} round trips/sweep)",
        f.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>(),
        report.temper.round_trips_per_sweep()
    );
    println!("  traces → results/fig9a_temper_{{anneal,temper}}.csv");

    // --shards N: the same ladder sharded across N software dies with
    // cross-worker swap phases (sw engine only — the sharded protocol
    // needs per-chain β on every die). --pipeline swaps the barrier
    // schedule for the 1-phase-lag pipelined one (serial retained as
    // the default), and works for a single die too. --elastic survives
    // die loss by re-partitioning the ladder over the survivors (the
    // membership log prints to stderr); combined with --fault-plan the
    // gang runs through the chip-array server so the scripted faults
    // land under specific dies, and with --net-plan it runs over the
    // in-process network simulator so scripted link impairments land
    // on the coordinator↔die lanes instead.
    let shards: usize = args.get("shards", 1)?;
    let pipeline = args.flag("pipeline");
    let elastic = args.flag("elastic");
    if shards > 1 || pipeline || elastic {
        anyhow::ensure!(
            shards <= replicas,
            "--shards {shards} cannot exceed --replicas {replicas}"
        );
        let sharded_params = pchip::coordinator::ShardedTemperingParams {
            base: temper_params.clone(),
            shards,
            barrier_timeout: std::time::Duration::from_millis(
                args.get("barrier-timeout-ms", 30_000u64)?,
            ),
            pipeline,
            elastic,
        };
        if let Some(plan) = net_plan(args)? {
            anyhow::ensure!(
                fault_plan(args)?.is_none(),
                "--fault-plan injects chip faults, --net-plan link faults; pick one per run"
            );
            let topo = Topology::new();
            let problem = pchip::problems::sk::chimera_pm_j(&topo, seed);
            let (samplers, scale) = exp::sharded_die_array(
                &sharded_params,
                &problem,
                cfg.mismatch,
                replicas.max(8) / shards.max(1),
                0xD1E5,
                |s| seed ^ 0xB04D ^ ((s as u64) << 8),
            )?;
            let r = pchip::coordinator::run_sharded_tempering_simnet(
                samplers,
                &problem,
                &sharded_params,
                scale,
                &plan,
                |_, _, _| {},
            )?;
            print_membership(&r.membership);
            println!(
                "sharded over simulated network: best {:.0} ({} shard(s) at the end{})",
                r.run.best_energy,
                r.shards,
                if r.membership.is_empty() { "" } else { ", membership log on stderr" }
            );
            for (k, l) in r.net.iter().enumerate() {
                pchip::log_info!(
                    "link {k}: down {}/{} delivered ({} dropped, {} dup, {} reordered), \
                     up {}/{} ({} dropped, {} dup, {} reordered)",
                    l.down.delivered,
                    l.down.sent,
                    l.down.dropped,
                    l.down.duplicated,
                    l.down.reordered,
                    l.up.delivered,
                    l.up.sent,
                    l.up.dropped,
                    l.up.duplicated,
                    l.up.reordered
                );
            }
            trace.export(r.telemetry.as_ref(), &r.run.trace.jsonl_rows())?;
            return Ok(());
        }
        if let Some(plan) = fault_plan(args)? {
            let mut scfg = cfg.clone();
            scfg.server.chips = shards;
            let engine = EngineKind::SoftwareFaulty { batch: replicas.max(8), plan };
            let srv = ChipArrayServer::start(&scfg, engine)?;
            let topo = Topology::new();
            let h = srv.register_problem(pchip::problems::sk::chimera_pm_j(&topo, seed))?;
            match srv.run_sharded_tempering(h, &sharded_params)? {
                JobResult::ShardedTempered {
                    best_energy,
                    shards: final_shards,
                    membership,
                    ..
                } => {
                    print_membership(&membership);
                    println!(
                        "sharded under fault plan: best {best_energy:.0} \
                         ({final_shards} shard(s) at the end{})",
                        if membership.is_empty() { "" } else { ", membership log on stderr" }
                    );
                }
                JobResult::Failed(msg) => bail!("sharded tempering failed: {msg}"),
                other => bail!("unexpected result {other:?}"),
            }
            // the run happened server-side; only the cumulative rollup
            // (this process's coordinator view) is available here
            trace.export(trace.cumulative_summary().as_ref(), &[])?;
            return Ok(());
        }
        let r = exp::fig9a_sk_temper_sharded(
            seed,
            &sharded_params,
            cfg.mismatch,
            replicas.max(8) / shards.max(1),
            Some("fig9a_sharded"),
        )?;
        print_membership(&r.sharded.membership);
        println!(
            "sharded ({shards} die(s), {} rungs each ±1{}): best {:.0} vs single-die {:.0}",
            replicas / shards,
            if pipeline { ", pipelined 1-phase-lag schedule" } else { "" },
            r.sharded.run.best_energy,
            r.single.best_energy
        );
        let bacc = r.sharded.boundary_acceptance();
        println!(
            "  merged swaps: mean acceptance {:.2}, boundary acceptance {:?}, \
             cross-shard round trips {}",
            r.sharded.run.swaps.mean_acceptance(),
            bacc.iter().map(|a| (a * 100.0).round() / 100.0).collect::<Vec<_>>(),
            r.sharded.cross_shard_round_trips()
        );
        println!("  traces → results/fig9a_sharded_{{single,sharded}}.csv");
        trace.export(r.sharded.telemetry.as_ref(), &r.sharded.run.trace.jsonl_rows())?;
        return Ok(());
    }
    // single-die path: no gang rollup, but the energy trace still rides
    // along with whatever the cumulative capture recorded
    trace.export(trace.cumulative_summary().as_ref(), &report.temper.trace.jsonl_rows())?;
    Ok(())
}

/// `pchip worker --connect HOST:PORT`: one remote die. Rebuilds the die
/// its seat would hold in the coordinator's in-process array — same
/// seeds, same mismatch personality, same problem codes — dials the
/// `--listen`ing coordinator and serves the seat's command loop until
/// the run finishes or the link dies for good (reconnect-backoff
/// exhausted). Bit-identical to the in-process run by construction;
/// `rust/tests/transport_socket.rs` holds the proof.
fn cmd_worker(args: &Args) -> Result<()> {
    use pchip::sampler::Sampler as _;
    use pchip::transport::SocketEndpoint;

    let cfg = load_config(args)?;
    let addr = args
        .value_of("connect")?
        .ok_or_else(|| anyhow!("worker needs --connect HOST:PORT"))?
        .to_string();
    let seat: usize = args.get("seat", 0)?;
    let sock = socket_config_from_args(args)?;
    let protocol = args.str_or("protocol", "temper");
    match protocol.as_str() {
        "temper" => {
            use pchip::coordinator::{ShardCmd, ShardMsg};
            // mirror cmd_temper's flag set so the rebuilt die is the one
            // the coordinator's in-process run would have seated
            let b0: f64 = args.get("b0", 0.08)?;
            let b1: f64 = args.get("b1", 4.0)?;
            let replicas: usize = args.get("replicas", 8)?;
            let shards: usize = args.get("shards", 1)?;
            let seed = args.get("seed", 1u64)?;
            anyhow::ensure!(seat < shards, "--seat {seat} out of range for --shards {shards}");
            let die_params = pchip::coordinator::ShardedTemperingParams {
                base: pchip::annealing::TemperingParams {
                    ladder: pchip::annealing::BetaLadder::geometric(b0, b1, replicas),
                    ..Default::default()
                },
                shards,
                ..Default::default()
            };
            let topo = Topology::new();
            let problem = pchip::problems::sk::chimera_pm_j(&topo, seed);
            // exactly the constants cmd_temper's in-process gang paths
            // use, so seat K's die is bit-identical to the local one
            let (mut chips, _scale) = exp::sharded_die_array(
                &die_params,
                &problem,
                cfg.mismatch,
                replicas.max(8) / shards.max(1),
                0xD1E5,
                |s| seed ^ 0xB04D ^ ((s as u64) << 8),
            )?;
            let mut chip = chips.swap_remove(seat); // the other seats drop
            println!("dialing {addr} for tempering seat {seat}/{shards}…");
            let ep = SocketEndpoint::<ShardCmd, ShardMsg>::connect(addr.as_str(), seat, sock)?;
            println!("seated; serving die {seat} until the run finishes");
            pchip::coordinator::shard_worker_loop(seat, &mut chip, &problem, &ep);
        }
        "train" => {
            use pchip::learning::{TrainCmd, TrainMsg};
            let (_, params) = train_params_from_args(args)?;
            anyhow::ensure!(
                seat < params.dies,
                "--seat {seat} out of range for --dies {}",
                params.dies
            );
            // the same die the in-process array seats at shard `seat`:
            // personality seed cfg.server.seed + seat, batch 32, free
            // clamps, chains randomized from the seat seed
            let mut chip = exp::software_chip(cfg.server.seed + seat as u64, cfg.mismatch, 32);
            chip.set_clamps(&[]);
            chip.randomize(pchip::learning::service::seat_seed(params.seed, seat));
            println!("dialing {addr} for training seat {seat}/{}…", params.dies);
            let ep = SocketEndpoint::<TrainCmd, TrainMsg>::connect(addr.as_str(), seat, sock)?;
            println!("seated; serving die {seat} until the run finishes");
            pchip::learning::train_worker_loop(seat, &mut chip, &params, &ep);
        }
        other => bail!("unknown --protocol `{other}` (temper|train)"),
    }
    println!("worker seat {seat} done (run finished or link closed)");
    Ok(())
}

fn cmd_tune_ladder(args: &Args) -> Result<()> {
    use pchip::annealing::{BetaLadder, TemperingParams, TunerParams};
    let cfg = load_config(args)?;
    let b0: f64 = args.get("b0", 0.08)?;
    let b1: f64 = args.get("b1", 4.0)?;
    let replicas: usize = args.get("replicas", 8)?;
    anyhow::ensure!(replicas >= 2, "--replicas must be at least 2, got {replicas}");
    anyhow::ensure!(b0 > 0.0 && b1 > b0, "need 0 < --b0 < --b1, got {b0}..{b1}");
    let rounds: usize = args.get("rounds", 48)?;
    let seed = args.get("seed", 1u64)?;
    let tuner = TunerParams {
        base: TemperingParams {
            ladder: BetaLadder::geometric(b0, b1, replicas),
            sweeps_per_round: args.get("sweeps-per-round", 8)?,
            rounds,
            record_every: 8,
            seed: args.get("swap-seed", 0x9A77u64)?,
            ..Default::default()
        },
        max_iters: args.get("iters", 12)?,
        tol: args.get("tol", 0.02)?,
        acceptance_floor: args.get("floor", 0.2)?,
        redundancy_ceiling: args.get("ceiling", 0.9)?,
        min_k: args.get("min-k", 4)?,
        max_k: args.get("max-k", 32)?,
    };
    // give the auto-sizer room to grow up to max_k replicas on the die
    let batch = tuner.max_k.max(replicas).max(8);
    let eval_rounds: usize = args.get("eval-rounds", rounds * 2)?;
    let report = with_chip(args, &cfg, batch, |mut chip| {
        exp::fig9a_sk_ladder_tuning(&mut chip, seed, &tuner, eval_rounds, Some("tune_ladder"))
    })?;
    let t = &report.tuned;
    println!(
        "tuned ladder for SK seed {seed}: K {} ({}) after {} iteration(s), {} tuning sweeps",
        t.k(),
        if t.converged { "converged" } else { "NOT converged" },
        t.iterations.len(),
        t.total_sweeps,
    );
    for (i, it) in t.iterations.iter().enumerate() {
        println!(
            "  iter {i}: K {:>2}  acc min {:.2} mean {:.2}  round trips {:>3}  \
             shift {:.3}  {:?}",
            it.k, it.min_acceptance, it.mean_acceptance, it.round_trips, it.max_shift, it.action
        );
    }
    println!(
        "  β ladder: {:?}",
        t.ladder.betas.iter().map(|b| (b * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    println!(
        "  f(β): {:?}  (labeled {:.0}%)",
        t.f_profile.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>(),
        t.flux.labeled_fraction() * 100.0
    );
    println!(
        "evaluation over {eval_rounds} rounds at K {}: round trips/sweep \
         tuned {:.4} vs geometric {:.4}",
        report.tuned_run.ladder.len(),
        report.tuned_round_trips_per_sweep(),
        report.geometric_round_trips_per_sweep()
    );
    println!("  per-rung series → results/tune_ladder.csv");
    Ok(())
}

fn cmd_maxcut(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let topo = Topology::new();
    let params = AnnealParams {
        schedule: BetaSchedule::Geometric { b0: 0.2, b1: 4.0 },
        steps: args.get("steps", 48)?,
        sweeps_per_step: args.get("sweeps-per-step", 4)?,
        record_every: 1,
    };
    let clique_n: usize = args.get("clique-n", 0)?;
    let report = if clique_n > 0 {
        anyhow::ensure!(clique_n % 4 == 0 && clique_n <= 28, "--clique-n must be 4·t ≤ 28");
        let g = Graph::random(clique_n, 0.7, args.get("seed", 2)?);
        let emb = pchip::chimera::Embedding::clique(&topo, clique_n / 4, 1.5)?;
        let p = g.to_ising_embedded(&topo, &emb)?;
        with_chip(args, &cfg, 8, |mut chip| {
            exp::fig9b_maxcut(&mut chip, &g, &p, &params, Some(&emb), Some("fig9b_maxcut"))
        })?
    } else {
        let keep: f64 = args.get("native-keep", 0.6)?;
        let g = Graph::chimera_native(&topo, keep, args.get("seed", 2)?);
        let p = g.to_ising_native(&topo)?;
        with_chip(args, &cfg, 8, |mut chip| {
            exp::fig9b_maxcut(&mut chip, &g, &p, &params, None, Some("fig9b_maxcut"))
        })?
    };
    println!(
        "max-cut: chip {:.0} | greedy {:.0} | exact {} | W {:.0}",
        report.chip_best_cut,
        report.greedy_cut,
        report.exact_cut.map(|c| format!("{c:.0}")).unwrap_or_else(|| "n/a".into()),
        report.total_weight
    );
    println!("  trace → results/fig9b_maxcut.csv");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n_pbits: usize = args.get("pbits", 24)?;
    let points: usize = args.get("points", 33)?;
    let pbits: Vec<usize> = (0..n_pbits).map(|k| (k * 18) % pchip::N_SPINS).collect();
    let codes: Vec<i8> = (0..points)
        .map(|i| (-120 + (240 * i / (points - 1).max(1)) as i32) as i8)
        .collect();
    let samples: usize = args.get("samples", 2000)?;
    let report = with_chip(args, &cfg, 8, |mut chip| {
        exp::fig8a_bias_sweep(&mut chip, &pbits, &codes, samples, 1.0, Some("fig8a_sweep"))
    })?;
    println!(
        "bias sweep over {} p-bits: slope CV {:.3}, offset σ {:.2} codes",
        pbits.len(),
        report.slope_cv,
        report.offset_sd_codes
    );
    println!("  curves → results/fig8a_sweep.csv");
    Ok(())
}

fn cmd_tts(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let restarts: usize = args.get("restarts", 24)?;
    let params = exp::table1::default_tts_params();
    let seed = args.get("seed", 3u64)?;
    let report = with_chip(args, &cfg, 8, |mut chip| {
        exp::table1_tts(&mut chip, seed, restarts, &params, Some("table1_tts"))
    })?;
    println!(
        "TTS(99%): {:.0} ns  (p_success {:.3}, restart {:.0} ns, {} restarts)",
        report.tts.tts99_ns, report.p_success, report.chip_time_per_restart_ns, restarts
    );
    println!(
        "  chip-referred {:.2e} flips/s; host engine {:.2e} flips/s",
        report.chip_flips_per_sec, report.host_flips_per_sec
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    cfg.server.chips = args.get("chips", cfg.server.chips)?;
    let jobs: usize = args.get("jobs", 64)?;
    let engine = match args.str_or("engine", "sw").as_str() {
        "sw" => EngineKind::Software,
        "xla" => EngineKind::Xla { artifacts_dir: cfg.artifacts_dir() },
        other => bail!("unknown engine `{other}`"),
    };
    let srv = ChipArrayServer::start(&cfg, engine)?;
    let topo = Topology::new();
    // a mixed workload over three problems
    let h1 = srv.register_problem(pchip::problems::sk::chimera_pm_j(&topo, 1))?;
    let h2 = srv.register_problem(pchip::problems::sk::chimera_gaussian(&topo, 2))?;
    let g = Graph::chimera_native(&topo, 0.5, 3);
    let h3 = srv.register_problem(g.to_ising_native(&topo)?)?;
    let handles = [h1, h2, h3];
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            srv.submit(JobRequest::Sample {
                problem: handles[i % 3],
                sweeps: 32,
                beta: 1.5,
                chains: 4,
            })
        })
        .collect::<Result<_>>()?;
    let mut ok = 0;
    let mut lat_us: Vec<u64> = Vec::new();
    for t in tickets {
        match t.wait() {
            JobResult::Samples { latency, .. } => {
                ok += 1;
                lat_us.push(latency.as_micros() as u64);
            }
            JobResult::Failed(e) => pchip::log_warn!("job failed: {e}"),
            _ => {}
        }
    }
    lat_us.sort_unstable();
    let elapsed = t0.elapsed();
    let stats = srv.stats();
    use std::sync::atomic::Ordering;
    println!(
        "served {ok}/{jobs} jobs in {elapsed:.2?} ({:.0} jobs/s)",
        ok as f64 / elapsed.as_secs_f64()
    );
    if !lat_us.is_empty() {
        println!(
            "  latency p50 {} µs  p95 {} µs  p99 {} µs",
            lat_us[lat_us.len() / 2],
            lat_us[lat_us.len() * 95 / 100],
            lat_us[(lat_us.len() * 99 / 100).min(lat_us.len() - 1)]
        );
    }
    println!(
        "  batches {}  reprograms {}  chip-time {:.1} µs",
        stats.batches.load(Ordering::Relaxed),
        stats.reprograms.load(Ordering::Relaxed),
        stats.chip_time_ns.load(Ordering::Relaxed) as f64 / 1000.0
    );
    Ok(())
}
