//! Logical Ising problems and their lowering to chip register codes.
//!
//! Convention throughout: `E(m) = −Σ_{i<j} J_ij m_i m_j − Σ_i h_i m_i`,
//! so positive J is ferromagnetic and positive h favours +1.

use anyhow::{bail, Result};

use crate::chimera::{Topology, N_SPINS};

/// A problem over the hardware spins (after any embedding).
#[derive(Debug, Clone)]
pub struct IsingProblem {
    /// Sparse couplings `(i, j, J_ij)` with `i < j`, each a physical edge.
    pub couplings: Vec<(usize, usize, f64)>,
    /// Per-spin bias, length [`N_SPINS`].
    pub h: Vec<f64>,
    /// Human-readable tag for reports.
    pub name: String,
}

impl IsingProblem {
    /// Empty problem (no couplings, zero biases) with the given tag.
    pub fn new(name: impl Into<String>) -> Self {
        Self { couplings: Vec::new(), h: vec![0.0; N_SPINS], name: name.into() }
    }

    /// Validate that every coupling is a physical coupler.
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        for &(i, j, _) in &self.couplings {
            if i >= j {
                bail!("coupling ({i},{j}) not canonical (need i < j)");
            }
            if !topo.connected(i, j) {
                bail!("({i},{j}) is not a physical coupler");
            }
        }
        Ok(())
    }

    /// Ising energy of a ±1 state.
    pub fn energy(&self, m: &[i8]) -> f64 {
        let mut e = 0.0;
        for &(i, j, w) in &self.couplings {
            e -= w * (m[i] as f64) * (m[j] as f64);
        }
        for (i, &hh) in self.h.iter().enumerate() {
            if hh != 0.0 {
                e -= hh * m[i] as f64;
            }
        }
        e
    }

    /// Spins that carry any coupling or bias (the problem's support).
    pub fn support(&self) -> Vec<usize> {
        let mut used = vec![false; N_SPINS];
        for &(i, j, _) in &self.couplings {
            used[i] = true;
            used[j] = true;
        }
        for (i, &hh) in self.h.iter().enumerate() {
            if hh != 0.0 {
                used[i] = true;
            }
        }
        (0..N_SPINS).filter(|&i| used[i]).collect()
    }

    /// Largest coefficient magnitude (the 8-bit full-scale reference).
    pub fn max_abs(&self) -> f64 {
        let cj = self.couplings.iter().map(|&(_, _, w)| w.abs()).fold(0.0, f64::max);
        let ch = self.h.iter().map(|x| x.abs()).fold(0.0, f64::max);
        cj.max(ch)
    }

    /// Lower to 8-bit register codes: scale so `max_abs` maps to ±127,
    /// enable exactly the used couplers. Returns (j_codes, enables,
    /// h_codes, scale) where `J_physical = code/127 × scale`.
    pub fn to_codes(&self, topo: &Topology) -> Result<(Vec<i8>, Vec<bool>, Vec<i8>, f64)> {
        self.validate(topo)?;
        let scale = self.max_abs();
        if scale == 0.0 {
            let ne = topo.edges.len();
            return Ok((vec![0; ne], vec![false; ne], vec![0; N_SPINS], 1.0));
        }
        let mut j_codes = vec![0i8; topo.edges.len()];
        let mut enables = vec![false; topo.edges.len()];
        for &(i, j, w) in &self.couplings {
            let e = edge_index(topo, i, j).expect("validated edge");
            j_codes[e] = quantize(w / scale);
            enables[e] = true;
        }
        let h_codes = self.h.iter().map(|&x| quantize(x / scale)).collect();
        Ok((j_codes, enables, h_codes, scale))
    }

    /// The effective β a chip must run at so that `β_chip · J_code/127`
    /// equals `β_logical · J`: β_chip = β_logical × scale.
    pub fn beta_for(&self, beta_logical: f64) -> f64 {
        beta_logical * self.max_abs().max(f64::MIN_POSITIVE)
    }
}

/// Canonical edge index of (i, j), i < j (binary search on the sorted
/// edge list).
pub fn edge_index(topo: &Topology, i: usize, j: usize) -> Option<usize> {
    let key = (i.min(j), i.max(j));
    topo.edges.binary_search(&key).ok()
}

fn quantize(x: f64) -> i8 {
    (x * 127.0).round().clamp(-127.0, 127.0) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new()
    }

    #[test]
    fn energy_golden() {
        let t = topo();
        let mut p = IsingProblem::new("pair");
        let (i, j) = t.edges[0];
        p.couplings.push((i, j, 1.0));
        p.h[i] = 0.5;
        let mut m = vec![1i8; N_SPINS];
        assert_eq!(p.energy(&m), -1.5);
        m[j] = -1;
        assert_eq!(p.energy(&m), 0.5);
    }

    #[test]
    fn validate_rejects_non_edges() {
        let t = topo();
        let mut p = IsingProblem::new("bad");
        p.couplings.push((0, 1, 1.0)); // same-side pair: not a coupler
        assert!(p.validate(&t).is_err());
        let mut q = IsingProblem::new("swapped");
        let (i, j) = t.edges[0];
        q.couplings.push((j, i, 1.0));
        assert!(q.validate(&t).is_err());
    }

    #[test]
    fn codes_roundtrip_scale() {
        let t = topo();
        let mut p = IsingProblem::new("scaled");
        let (a, b) = t.edges[0];
        let (c, d) = t.edges[10];
        p.couplings.push((a, b, 2.0));
        p.couplings.push((c, d, -1.0));
        p.h[a] = 0.5;
        let (j_codes, enables, h_codes, scale) = p.to_codes(&t).unwrap();
        assert_eq!(scale, 2.0);
        assert_eq!(j_codes[0], 127);
        assert_eq!(j_codes[10], -64); // −0.5 × 127 rounds to −64
        assert!(enables[0] && enables[10]);
        assert_eq!(enables.iter().filter(|&&e| e).count(), 2);
        assert_eq!(h_codes[a], 32); // 0.25 × 127 ≈ 31.75 → 32
    }

    #[test]
    fn edge_index_finds_all() {
        let t = topo();
        for (e, &(i, j)) in t.edges.iter().enumerate() {
            assert_eq!(edge_index(&t, i, j), Some(e));
            assert_eq!(edge_index(&t, j, i), Some(e));
        }
        assert_eq!(edge_index(&t, 0, 1), None);
    }

    #[test]
    fn support_tracks_usage() {
        let t = topo();
        let mut p = IsingProblem::new("s");
        let (i, j) = t.edges[5];
        p.couplings.push((i, j, 0.3));
        p.h[100] = -0.2;
        let s = p.support();
        assert!(s.contains(&i) && s.contains(&j) && s.contains(&100));
        assert_eq!(s.len(), 3);
    }
}
