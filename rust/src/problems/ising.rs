//! Logical Ising problems and their lowering to chip register codes.
//!
//! Convention throughout: `E(m) = −Σ_{i<j} J_ij m_i m_j − Σ_i h_i m_i`,
//! so positive J is ferromagnetic and positive h favours +1.

use anyhow::{bail, Result};

use crate::chimera::{Topology, N_SPINS};

/// A problem over the hardware spins (after any embedding).
#[derive(Debug, Clone)]
pub struct IsingProblem {
    /// Sparse couplings `(i, j, J_ij)` with `i < j`, each a physical edge.
    pub couplings: Vec<(usize, usize, f64)>,
    /// Per-spin bias, length [`N_SPINS`].
    pub h: Vec<f64>,
    /// Human-readable tag for reports.
    pub name: String,
}

impl IsingProblem {
    /// Empty problem (no couplings, zero biases) with the given tag.
    pub fn new(name: impl Into<String>) -> Self {
        Self { couplings: Vec::new(), h: vec![0.0; N_SPINS], name: name.into() }
    }

    /// Validate that every coupling is a physical coupler.
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        for &(i, j, _) in &self.couplings {
            if i >= j {
                bail!("coupling ({i},{j}) not canonical (need i < j)");
            }
            if !topo.connected(i, j) {
                bail!("({i},{j}) is not a physical coupler");
            }
        }
        Ok(())
    }

    /// Ising energy of a ±1 state.
    pub fn energy(&self, m: &[i8]) -> f64 {
        let mut e = 0.0;
        for &(i, j, w) in &self.couplings {
            e -= w * (m[i] as f64) * (m[j] as f64);
        }
        for (i, &hh) in self.h.iter().enumerate() {
            if hh != 0.0 {
                e -= hh * m[i] as f64;
            }
        }
        e
    }

    /// Spins that carry any coupling or bias (the problem's support).
    pub fn support(&self) -> Vec<usize> {
        let mut used = vec![false; N_SPINS];
        for &(i, j, _) in &self.couplings {
            used[i] = true;
            used[j] = true;
        }
        for (i, &hh) in self.h.iter().enumerate() {
            if hh != 0.0 {
                used[i] = true;
            }
        }
        (0..N_SPINS).filter(|&i| used[i]).collect()
    }

    /// Largest coefficient magnitude (the 8-bit full-scale reference).
    pub fn max_abs(&self) -> f64 {
        let cj = self.couplings.iter().map(|&(_, _, w)| w.abs()).fold(0.0, f64::max);
        let ch = self.h.iter().map(|x| x.abs()).fold(0.0, f64::max);
        cj.max(ch)
    }

    /// Lower to 8-bit register codes: scale so `max_abs` maps to ±127,
    /// enable exactly the used couplers. Returns (j_codes, enables,
    /// h_codes, scale) where `J_physical = code/127 × scale`.
    pub fn to_codes(&self, topo: &Topology) -> Result<(Vec<i8>, Vec<bool>, Vec<i8>, f64)> {
        self.validate(topo)?;
        let scale = self.max_abs();
        if scale == 0.0 {
            let ne = topo.edges.len();
            return Ok((vec![0; ne], vec![false; ne], vec![0; N_SPINS], 1.0));
        }
        let mut j_codes = vec![0i8; topo.edges.len()];
        let mut enables = vec![false; topo.edges.len()];
        for &(i, j, w) in &self.couplings {
            let e = edge_index(topo, i, j).expect("validated edge");
            j_codes[e] = quantize(w / scale);
            enables[e] = true;
        }
        let h_codes = self.h.iter().map(|&x| quantize(x / scale)).collect();
        Ok((j_codes, enables, h_codes, scale))
    }

    /// The effective β a chip must run at so that `β_chip · J_code/127`
    /// equals `β_logical · J`: β_chip = β_logical × scale.
    pub fn beta_for(&self, beta_logical: f64) -> f64 {
        beta_logical * self.max_abs().max(f64::MIN_POSITIVE)
    }
}

/// Canonical edge index of (i, j), i < j (binary search on the sorted
/// edge list).
pub fn edge_index(topo: &Topology, i: usize, j: usize) -> Option<usize> {
    let key = (i.min(j), i.max(j));
    topo.edges.binary_search(&key).ok()
}

/// Max couplers per p-bit on the Chimera die (the ledger's CSR width).
const LEDGER_DEG: usize = 6;

/// Incremental, integer code-domain energy accounting for a lowered
/// problem — the readback half of the pipelined tempering engine.
///
/// The samplers run on register codes: [`IsingProblem::to_codes`] maps
/// every coupling and bias to an 8-bit code plus one global `scale`
/// with `J = code/127 × scale`. In that domain the Hamiltonian
/// `E_code(m) = −Σ c_ij·m_i·m_j − Σ ch_i·m_i` is an **integer**, so a
/// per-flip delta `ΔE_code = 2·m_i·(Σ_j c_ij·m_j + ch_i)` can be
/// accumulated during the sweep in exact arithmetic: the running sum is
/// bit-identical to a full recompute no matter how many flips happened
/// in between — integer addition is associative, which is what makes
/// the O(deg)-per-flip readback provably equal to the O(N·deg) rescan
/// (pinned by `rust/tests/pipelined_equivalence.rs`). Logical readback
/// is `E = E_code × scale / 127`, equal to [`IsingProblem::energy`]
/// **exactly** whenever the lowering is lossless (±1 coefficients — the
/// SK and equivalence-suite instances).
///
/// Engines opt in through [`crate::sampler::Sampler::track_energies`];
/// the pure-rust sampler and the cycle-level chip update their ledgers
/// inside the sweep loop, so a tempering swap phase reads chain
/// energies in O(chains) instead of O(chains · N · deg).
#[derive(Debug, Clone)]
pub struct EnergyLedger {
    /// `[N_SPINS × LEDGER_DEG]` neighbor ids (padded with self, code 0).
    nbr_idx: Vec<u32>,
    /// `[N_SPINS × LEDGER_DEG]` coupling code into the target spin.
    nbr_c: Vec<i32>,
    /// Per-spin bias codes.
    h_c: Vec<i32>,
    /// Enabled `(i, j, code)` triples, in canonical edge order (the
    /// full-recompute path).
    edges: Vec<(u32, u32, i32)>,
    /// code → logical coupling scale (`J = code/127 × scale`).
    scale: f64,
}

impl EnergyLedger {
    /// Build the ledger from a problem's lossy-quantized register codes
    /// (fails only when the problem itself fails validation).
    pub fn new(problem: &IsingProblem, topo: &Topology) -> Result<Self> {
        let (j_codes, enables, h_codes, scale) = problem.to_codes(topo)?;
        let mut nbr_idx = vec![0u32; N_SPINS * LEDGER_DEG];
        let mut nbr_c = vec![0i32; N_SPINS * LEDGER_DEG];
        let mut fill = vec![0usize; N_SPINS];
        // pad every row with self (code 0) so the gather is branch-free
        for i in 0..N_SPINS {
            for k in 0..LEDGER_DEG {
                nbr_idx[i * LEDGER_DEG + k] = i as u32;
            }
        }
        let mut edges = Vec::new();
        for (e, &(i, j)) in topo.edges.iter().enumerate() {
            if !enables[e] || j_codes[e] == 0 {
                continue;
            }
            let c = j_codes[e] as i32;
            edges.push((i as u32, j as u32, c));
            for (a, b) in [(i, j), (j, i)] {
                let slot = a * LEDGER_DEG + fill[a];
                nbr_idx[slot] = b as u32;
                nbr_c[slot] = c;
                fill[a] += 1;
            }
        }
        Ok(Self {
            nbr_idx,
            nbr_c,
            h_c: h_codes.iter().map(|&c| c as i32).collect(),
            edges,
            scale,
        })
    }

    /// [`EnergyLedger::new`] with a freshly built hardware topology —
    /// what engine-side callers (worker threads holding only the
    /// problem) use.
    pub fn for_problem(problem: &IsingProblem) -> Result<Self> {
        Self::new(problem, &Topology::new())
    }

    /// Full code-domain energy of a ±1 state — the O(N·deg) rescan the
    /// incremental path replaces (and is checked against).
    pub fn full_code(&self, state: &[i8]) -> i64 {
        let mut e = 0i64;
        for &(i, j, c) in &self.edges {
            e -= c as i64 * (state[i as usize] * state[j as usize]) as i64;
        }
        for (i, &hc) in self.h_c.iter().enumerate() {
            if hc != 0 {
                e -= hc as i64 * state[i] as i64;
            }
        }
        e
    }

    /// Code-domain energy change of flipping spin `i` out of `state`
    /// (`state` is the *pre-flip* configuration) — O(deg), exact.
    #[inline]
    pub fn flip_delta(&self, state: &[i8], i: usize) -> i64 {
        let base = i * LEDGER_DEG;
        let mut field = self.h_c[i] as i64;
        for k in 0..LEDGER_DEG {
            field += self.nbr_c[base + k] as i64
                * state[self.nbr_idx[base + k] as usize] as i64;
        }
        2 * state[i] as i64 * field
    }

    /// Convert a code-domain energy to logical units. Computed as
    /// `e × scale / 127` in that order, so lossless codes (±1
    /// coefficients) reproduce [`IsingProblem::energy`] bit-for-bit.
    pub fn logical(&self, e_code: i64) -> f64 {
        e_code as f64 * self.scale / 127.0
    }
}

fn quantize(x: f64) -> i8 {
    (x * 127.0).round().clamp(-127.0, 127.0) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new()
    }

    #[test]
    fn energy_golden() {
        let t = topo();
        let mut p = IsingProblem::new("pair");
        let (i, j) = t.edges[0];
        p.couplings.push((i, j, 1.0));
        p.h[i] = 0.5;
        let mut m = vec![1i8; N_SPINS];
        assert_eq!(p.energy(&m), -1.5);
        m[j] = -1;
        assert_eq!(p.energy(&m), 0.5);
    }

    #[test]
    fn validate_rejects_non_edges() {
        let t = topo();
        let mut p = IsingProblem::new("bad");
        p.couplings.push((0, 1, 1.0)); // same-side pair: not a coupler
        assert!(p.validate(&t).is_err());
        let mut q = IsingProblem::new("swapped");
        let (i, j) = t.edges[0];
        q.couplings.push((j, i, 1.0));
        assert!(q.validate(&t).is_err());
    }

    #[test]
    fn codes_roundtrip_scale() {
        let t = topo();
        let mut p = IsingProblem::new("scaled");
        let (a, b) = t.edges[0];
        let (c, d) = t.edges[10];
        p.couplings.push((a, b, 2.0));
        p.couplings.push((c, d, -1.0));
        p.h[a] = 0.5;
        let (j_codes, enables, h_codes, scale) = p.to_codes(&t).unwrap();
        assert_eq!(scale, 2.0);
        assert_eq!(j_codes[0], 127);
        assert_eq!(j_codes[10], -64); // −0.5 × 127 rounds to −64
        assert!(enables[0] && enables[10]);
        assert_eq!(enables.iter().filter(|&&e| e).count(), 2);
        assert_eq!(h_codes[a], 32); // 0.25 × 127 ≈ 31.75 → 32
    }

    #[test]
    fn edge_index_finds_all() {
        let t = topo();
        for (e, &(i, j)) in t.edges.iter().enumerate() {
            assert_eq!(edge_index(&t, i, j), Some(e));
            assert_eq!(edge_index(&t, j, i), Some(e));
        }
        assert_eq!(edge_index(&t, 0, 1), None);
    }

    #[test]
    fn ledger_full_matches_logical_energy_on_pm1() {
        let t = topo();
        let mut p = IsingProblem::new("pm1");
        for (k, &(i, j)) in t.edges.iter().take(40).enumerate() {
            p.couplings.push((i, j, if k % 3 == 0 { -1.0 } else { 1.0 }));
        }
        p.h[2] = 1.0;
        p.h[9] = -1.0;
        let ledger = EnergyLedger::new(&p, &t).unwrap();
        let mut rng = crate::rng::HostRng::new(11);
        for _ in 0..20 {
            let st: Vec<i8> = (0..N_SPINS).map(|_| rng.spin()).collect();
            // ±1 coefficients lower losslessly: logical readback is exact
            assert_eq!(ledger.logical(ledger.full_code(&st)), p.energy(&st));
        }
    }

    #[test]
    fn ledger_flip_delta_matches_rescan() {
        let t = topo();
        let mut p = IsingProblem::new("mixed");
        for (k, &(i, j)) in t.edges.iter().take(60).enumerate() {
            p.couplings.push((i, j, 0.1 + 0.07 * k as f64));
        }
        p.h[0] = 0.4;
        let ledger = EnergyLedger::new(&p, &t).unwrap();
        let mut rng = crate::rng::HostRng::new(5);
        let mut st: Vec<i8> = (0..N_SPINS).map(|_| rng.spin()).collect();
        let mut e = ledger.full_code(&st);
        for _ in 0..200 {
            let i = rng.below(N_SPINS);
            e += ledger.flip_delta(&st, i);
            st[i] = -st[i];
            // integer arithmetic: the running sum is exactly the rescan
            assert_eq!(e, ledger.full_code(&st));
        }
    }

    #[test]
    fn support_tracks_usage() {
        let t = topo();
        let mut p = IsingProblem::new("s");
        let (i, j) = t.edges[5];
        p.couplings.push((i, j, 0.3));
        p.h[100] = -0.2;
        let s = p.support();
        assert!(s.contains(&i) && s.contains(&j) && s.contains(&100));
        assert_eq!(s.len(), 3);
    }
}
