//! Exact solvers for small problems: brute-force ground states and
//! Boltzmann distributions over a problem's support — the ground truth
//! every sampler is validated against.

use anyhow::{bail, Result};

use super::ising::IsingProblem;
use crate::chimera::N_SPINS;

/// Max support size for exhaustive enumeration (2^24 states).
const MAX_EXACT: usize = 24;

/// Brute-force ground state: returns (energy, one minimizing state over
/// the full spin vector with non-support spins set +1).
pub fn exact_ground_state(p: &IsingProblem) -> Result<(f64, Vec<i8>)> {
    let support = p.support();
    let k = support.len();
    if k > MAX_EXACT {
        bail!("support {k} too large for exact enumeration");
    }
    let mut best_e = f64::INFINITY;
    let mut best_bits = 0usize;
    let mut m = vec![1i8; N_SPINS];
    for bits in 0..(1usize << k) {
        for (b, &s) in support.iter().enumerate() {
            m[s] = if (bits >> b) & 1 == 1 { 1 } else { -1 };
        }
        let e = p.energy(&m);
        if e < best_e {
            best_e = e;
            best_bits = bits;
        }
    }
    for (b, &s) in support.iter().enumerate() {
        m[s] = if (best_bits >> b) & 1 == 1 { 1 } else { -1 };
    }
    Ok((best_e, m))
}

/// Exact Boltzmann distribution over the support at inverse temperature
/// `beta`: returns (states as bit-vectors over support order,
/// probabilities).
pub fn exact_boltzmann(p: &IsingProblem, beta: f64) -> Result<(Vec<Vec<i8>>, Vec<f64>)> {
    let support = p.support();
    let k = support.len();
    if k > 20 {
        bail!("support {k} too large for exact distribution");
    }
    let mut m = vec![1i8; N_SPINS];
    let mut energies = Vec::with_capacity(1 << k);
    let mut states = Vec::with_capacity(1 << k);
    for bits in 0..(1usize << k) {
        let mut s_vec = Vec::with_capacity(k);
        for (b, &s) in support.iter().enumerate() {
            let v = if (bits >> b) & 1 == 1 { 1i8 } else { -1i8 };
            m[s] = v;
            s_vec.push(v);
        }
        energies.push(p.energy(&m));
        states.push(s_vec);
    }
    let e_min = energies.iter().cloned().fold(f64::INFINITY, f64::min);
    let weights: Vec<f64> = energies.iter().map(|&e| (-beta * (e - e_min)).exp()).collect();
    let z: f64 = weights.iter().sum();
    Ok((states, weights.into_iter().map(|w| w / z).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chimera::Topology;

    #[test]
    fn ferro_pair_ground_state() {
        let t = Topology::new();
        let mut p = IsingProblem::new("pair");
        let (i, j) = t.edges[0];
        p.couplings.push((i, j, 1.0));
        let (e, m) = exact_ground_state(&p).unwrap();
        assert_eq!(e, -1.0);
        assert_eq!(m[i], m[j]);
    }

    #[test]
    fn frustrated_triangle_via_biases() {
        // two spins with antiferro coupling and aligned biases: ground
        // state balances bias against coupling.
        let t = Topology::new();
        let (i, j) = t.edges[0];
        let mut p = IsingProblem::new("afm");
        p.couplings.push((i, j, -1.0));
        p.h[i] = 0.4;
        p.h[j] = 0.4;
        let (e, m) = exact_ground_state(&p).unwrap();
        // anti-aligned wins: E = -(-1)(-1) ... check both configs:
        // aligned(++): E = 1 - 0.8 = 0.2 ; anti: E = -1 ± 0 = -1
        assert_eq!(e, -1.0);
        assert_ne!(m[i], m[j]);
    }

    #[test]
    fn boltzmann_sums_to_one_and_orders_by_energy() {
        let t = Topology::new();
        let (i, j) = t.edges[0];
        let mut p = IsingProblem::new("pair");
        p.couplings.push((i, j, 0.8));
        let (states, probs) = exact_boltzmann(&p, 1.0).unwrap();
        assert_eq!(states.len(), 4);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // aligned states (±,±) are the two most probable
        let mut idx: Vec<usize> = (0..4).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        for &k in &idx[..2] {
            assert_eq!(states[k][0], states[k][1]);
        }
    }

    #[test]
    fn too_large_support_rejected() {
        let t = Topology::new();
        let mut p = IsingProblem::new("big");
        for &(i, j) in t.edges.iter().take(100) {
            p.couplings.push((i, j, 1.0));
        }
        assert!(exact_ground_state(&p).is_err());
    }
}
