//! Max-Cut instances and baselines (the Fig 9b workload).
//!
//! Max-Cut(G, w): partition vertices to maximize the weight of edges
//! crossing the cut. As Ising: with J_ij = −w_ij (antiferromagnetic),
//! `cut(m) = (W − Σ w_ij m_i m_j)/2 = (W + E_J(m))/…` — concretely
//! `cut = (W - Σ_{ij} w_ij m_i m_j) / 2` and minimizing the Ising energy
//! maximizes the cut.

use anyhow::Result;

use crate::chimera::{Embedding, Topology};
use crate::rng::HostRng;

use super::ising::IsingProblem;

/// An undirected weighted graph.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Vertex count.
    pub n: usize,
    /// (u, v, w) with u < v.
    pub edges: Vec<(usize, usize, f64)>,
}

impl Graph {
    /// Erdős–Rényi G(n, p) with unit weights.
    pub fn random(n: usize, p: f64, seed: u64) -> Self {
        let mut rng = HostRng::new(seed ^ 0xC0C0);
        let mut edges = Vec::new();
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.uniform() < p {
                    edges.push((u, v, 1.0));
                }
            }
        }
        Self { n, edges }
    }

    /// A random subgraph of the Chimera hardware graph itself over all
    /// 440 spins (natively embeddable — the realistic chip workload).
    pub fn chimera_native(topo: &Topology, keep: f64, seed: u64) -> Self {
        let mut rng = HostRng::new(seed ^ 0x11AD);
        let edges = topo
            .edges
            .iter()
            .filter(|_| rng.uniform() < keep)
            .map(|&(i, j)| (i, j, 1.0))
            .collect();
        Self { n: crate::N_SPINS, edges }
    }

    /// Sum of all edge weights (W — the cut's upper bound).
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|&(_, _, w)| w).sum()
    }

    /// Cut value of a ±1 assignment.
    pub fn cut_value(&self, m: &[i8]) -> f64 {
        self.edges
            .iter()
            .map(|&(u, v, w)| if m[u] != m[v] { w } else { 0.0 })
            .sum()
    }

    /// Lower to an Ising problem on the hardware graph. For native
    /// graphs this is the identity mapping; otherwise pass an embedding.
    pub fn to_ising_native(&self, topo: &Topology) -> Result<IsingProblem> {
        let mut p = IsingProblem::new("maxcut-native");
        for &(u, v, w) in &self.edges {
            p.couplings.push((u.min(v), u.max(v), -w));
        }
        p.validate(topo)?;
        Ok(p)
    }

    /// Lower through a minor embedding (for non-native graphs, e.g. a
    /// K_n instance via the TRIAD clique embedding).
    pub fn to_ising_embedded(
        &self,
        topo: &Topology,
        emb: &Embedding,
    ) -> Result<IsingProblem> {
        let mut jl = vec![vec![0.0; self.n]; self.n];
        for &(u, v, w) in &self.edges {
            jl[u][v] = -w;
            jl[v][u] = -w;
        }
        let hl = vec![0.0; self.n];
        let (j_phys, h_phys) = emb.embed(topo, &jl, &hl)?;
        let mut p = IsingProblem::new("maxcut-embedded");
        // merge duplicate physical couplers (chain + logical shares)
        let mut acc = std::collections::BTreeMap::new();
        for (i, j, w) in j_phys {
            *acc.entry((i, j)).or_insert(0.0) += w;
        }
        p.couplings = acc.into_iter().map(|((i, j), w)| (i, j, w)).collect();
        p.h = h_phys;
        p.validate(topo)?;
        Ok(p)
    }

    /// Greedy local-search baseline: start random, flip any vertex that
    /// improves the cut until a local optimum; best of `restarts`.
    pub fn greedy_baseline(&self, restarts: usize, seed: u64) -> (f64, Vec<i8>) {
        let mut rng = HostRng::new(seed ^ 0x64EE);
        let mut best = (f64::NEG_INFINITY, vec![1i8; self.n]);
        // adjacency for O(deg) flip deltas
        let mut adj = vec![Vec::new(); self.n];
        for &(u, v, w) in &self.edges {
            adj[u].push((v, w));
            adj[v].push((u, w));
        }
        for _ in 0..restarts {
            let mut m: Vec<i8> = (0..self.n).map(|_| rng.spin()).collect();
            loop {
                let mut improved = false;
                for u in 0..self.n {
                    // delta = (cut with u flipped) - (current cut)
                    let delta: f64 = adj[u]
                        .iter()
                        .map(|&(v, w)| if m[u] == m[v] { w } else { -w })
                        .sum();
                    if delta > 1e-12 {
                        m[u] = -m[u];
                        improved = true;
                    }
                }
                if !improved {
                    break;
                }
            }
            let c = self.cut_value(&m);
            if c > best.0 {
                best = (c, m);
            }
        }
        best
    }

    /// Exact max cut by enumeration (n ≤ 24).
    pub fn exact_max_cut(&self) -> Result<f64> {
        anyhow::ensure!(self.n <= 24, "n={} too large for exact max-cut", self.n);
        let mut best = 0.0f64;
        for bits in 0..(1usize << (self.n - 1)) {
            // fix vertex n-1 on side +1 (cut symmetric under global flip)
            let m: Vec<i8> = (0..self.n)
                .map(|v| if v < self.n - 1 && (bits >> v) & 1 == 1 { -1 } else { 1 })
                .collect();
            best = best.max(self.cut_value(&m));
        }
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_value_simple_triangle() {
        let g = Graph { n: 3, edges: vec![(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)] };
        assert_eq!(g.cut_value(&[1, -1, 1]), 2.0);
        assert_eq!(g.cut_value(&[1, 1, 1]), 0.0);
        assert_eq!(g.exact_max_cut().unwrap(), 2.0);
    }

    #[test]
    fn ising_energy_tracks_cut() {
        // cut = (W − Σ w·m·m)/2 and E_ising = Σ w·m·m (J = −w) ⇒
        // cut = (W + (−E? )) … verify numerically instead:
        let t = Topology::new();
        let g = Graph::chimera_native(&t, 0.5, 1);
        let p = g.to_ising_native(&t).unwrap();
        let mut rng = HostRng::new(2);
        for _ in 0..10 {
            let m: Vec<i8> = (0..crate::N_SPINS).map(|_| rng.spin()).collect();
            let cut = g.cut_value(&m);
            // E = −Σ J m m = Σ w m m ⇒ cut = (W − E_signed)/2 where
            // E_signed = Σ w m m = p.energy (since h = 0, E = −Σ J mm).
            let e = p.energy(&m);
            assert!((cut - (g.total_weight() - e) / 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn greedy_reaches_exact_on_small_graphs() {
        for seed in 0..5 {
            let g = Graph::random(10, 0.5, seed);
            if g.edges.is_empty() {
                continue;
            }
            let exact = g.exact_max_cut().unwrap();
            let (greedy, m) = g.greedy_baseline(20, seed);
            assert_eq!(greedy, g.cut_value(&m));
            assert!(greedy >= 0.8 * exact, "greedy {greedy} vs exact {exact}");
        }
    }

    #[test]
    fn native_graph_validates() {
        let t = Topology::new();
        let g = Graph::chimera_native(&t, 0.8, 3);
        assert!(!g.edges.is_empty());
        g.to_ising_native(&t).unwrap();
    }

    #[test]
    fn embedded_k8_lowered() {
        let t = Topology::new();
        let g = Graph::random(8, 0.9, 4);
        let emb = Embedding::clique(&t, 2, 2.0).unwrap();
        let p = g.to_ising_embedded(&t, &emb).unwrap();
        assert!(!p.couplings.is_empty());
        // chain couplers are ferromagnetic (positive J)
        assert!(p.couplings.iter().any(|&(_, _, w)| w > 0.0));
        // logical maxcut couplers are antiferromagnetic
        assert!(p.couplings.iter().any(|&(_, _, w)| w < 0.0));
    }
}
