//! Ising problem library: everything the paper's evaluation runs.
//!
//! * [`IsingProblem`] — logical-level couplings/biases with energy,
//!   8-bit code lowering, and exact enumeration for small instances.
//! * [`sk`] — Chimera-structured ±J spin glass over all 440 spins
//!   (Fig 9a; a literal Sherrington–Kirkpatrick all-to-all cannot embed
//!   natively — see DESIGN.md substitutions).
//! * [`maxcut`] — Max-Cut instances (Fig 9b) with greedy / exact
//!   baselines.

mod exact;
pub mod ising;
pub mod maxcut;
pub mod sk;

pub use exact::{exact_boltzmann, exact_ground_state};
pub use ising::{edge_index, EnergyLedger, IsingProblem};
