//! Chimera-structured ±J spin glass (the Fig 9a workload).
//!
//! The paper anneals "a Sherrington-Kirkpatrick spin-glass" over all 440
//! spins. A literal SK model is all-to-all and cannot natively embed in
//! Chimera at this size; consistent with standard practice for this
//! topology (and with what 440 physical spins can realize), we draw an
//! independent ±J (or Gaussian) coupling on **every hardware coupler**,
//! which preserves the experiment's point: a frustrated glass whose
//! energy falls as V_temp anneals. DESIGN.md §substitutions records this.

use crate::chimera::Topology;
use crate::rng::HostRng;

use super::ising::IsingProblem;

/// ±J glass on every hardware coupler.
pub fn chimera_pm_j(topo: &Topology, seed: u64) -> IsingProblem {
    let mut rng = HostRng::new(seed ^ 0x51C7);
    let mut p = IsingProblem::new(format!("chimera-pmJ-{seed}"));
    for &(i, j) in &topo.edges {
        p.couplings.push((i, j, rng.spin() as f64));
    }
    p
}

/// Gaussian glass (J ~ N(0, 1)) on every hardware coupler — closer in
/// spirit to SK's Gaussian couplings.
pub fn chimera_gaussian(topo: &Topology, seed: u64) -> IsingProblem {
    let mut rng = HostRng::new(seed ^ 0x6A55);
    let mut p = IsingProblem::new(format!("chimera-gauss-{seed}"));
    for &(i, j) in &topo.edges {
        p.couplings.push((i, j, rng.normal()));
    }
    p
}

/// A small planted-solution glass: couplings are chosen so a hidden
/// random state is the ground state (J_ij = s_i s_j) — gives TTS
/// experiments a known target energy.
pub fn planted(topo: &Topology, seed: u64) -> (IsingProblem, Vec<i8>, f64) {
    let mut rng = HostRng::new(seed ^ 0x9147);
    let hidden: Vec<i8> = (0..crate::N_SPINS).map(|_| rng.spin()).collect();
    let mut p = IsingProblem::new(format!("planted-{seed}"));
    for &(i, j) in &topo.edges {
        p.couplings.push((i, j, (hidden[i] * hidden[j]) as f64));
    }
    let e0 = p.energy(&hidden);
    (p, hidden, e0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pm_j_covers_all_edges_with_unit_weights() {
        let t = Topology::new();
        let p = chimera_pm_j(&t, 1);
        assert_eq!(p.couplings.len(), t.edges.len());
        assert!(p.couplings.iter().all(|&(_, _, w)| w == 1.0 || w == -1.0));
        p.validate(&t).unwrap();
        // roughly balanced signs
        let plus = p.couplings.iter().filter(|&&(_, _, w)| w > 0.0).count();
        let frac = plus as f64 / p.couplings.len() as f64;
        assert!((frac - 0.5).abs() < 0.1, "sign balance {frac}");
    }

    #[test]
    fn gaussian_moments() {
        let t = Topology::new();
        let p = chimera_gaussian(&t, 2);
        let n = p.couplings.len() as f64;
        let mean: f64 = p.couplings.iter().map(|&(_, _, w)| w).sum::<f64>() / n;
        let var: f64 = p.couplings.iter().map(|&(_, _, w)| (w - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.1);
        assert!((var - 1.0).abs() < 0.2);
    }

    #[test]
    fn planted_state_is_a_ground_state() {
        let t = Topology::new();
        let (p, hidden, e0) = planted(&t, 3);
        // planted energy = −(number of edges); no state can do better
        assert_eq!(e0, -(t.edges.len() as f64));
        assert_eq!(p.energy(&hidden), e0);
        // flipping one spin must not lower the energy
        let mut m = hidden.clone();
        m[7] = -m[7];
        assert!(p.energy(&m) > e0);
    }

    #[test]
    fn seeds_give_distinct_instances() {
        let t = Topology::new();
        let a = chimera_pm_j(&t, 1);
        let b = chimera_pm_j(&t, 2);
        let same = a
            .couplings
            .iter()
            .zip(&b.couplings)
            .filter(|((_, _, x), (_, _, y))| x == y)
            .count();
        assert!(same < a.couplings.len() * 6 / 10);
    }
}
