#!/usr/bin/env python3
"""Perf-regression gate for the sampler hot-path bench.

Compares a freshly measured ``BENCH_hotpath.json`` against the committed
baseline (``rust/benches/baselines/BENCH_hotpath.json``) and fails CI
when:

* the packed kernel's speedup over the best scalar arm at batch >= 32
  (``packed_speedup_batch32``, computed by the bench itself on the
  *fresh* machine, so both sides of the ratio share one noise level)
  falls below ``--min-speedup``; or
* any arm present in both reports regresses by more than
  ``--max-regression`` relative to the baseline.

Baselines carry a ``"provisional": true`` flag when they were recorded
on a different class of machine than CI (e.g. seeded by a dev box); a
provisional baseline skips the per-arm regression comparison (absolute
flips/s do not transfer across machines) but still enforces the speedup
ratio, which does. Re-record the baseline from a CI artifact to drop
the flag:  cp BENCH_hotpath.json rust/benches/baselines/  (and delete
the "provisional" key).
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def arm_map(report):
    """(arm, batch) -> flips/s for every measured arm."""
    return {
        (a["arm"], a["batch"]): a["flips_per_sec"]
        for a in report.get("arms", [])
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly measured BENCH_hotpath.json")
    ap.add_argument("baseline", help="committed baseline BENCH_hotpath.json")
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=5.0,
        help="minimum packed/scalar speedup at batch >= 32 (default 5.0)",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="maximum fractional per-arm slowdown vs baseline (default 0.20)",
    )
    args = ap.parse_args()

    fresh = load(args.fresh)
    base = load(args.baseline)
    failures = []

    # Derived flips/s rollup (display only, no gate): the bench's best
    # software arm against the paper's silicon rate, plus the measured
    # telemetry recording overhead when the report carries it.
    best = fresh.get("best_flips_per_sec")
    if best:
        line = f"best arm: {best:.3e} flips/s"
        silicon = fresh.get("silicon_flips_per_sec")
        if silicon:
            line += f" ({best / silicon:.1%} of the silicon rate)"
        print(line)
    overhead = fresh.get("telemetry_overhead_pct")
    if overhead is not None:
        print(f"telemetry recording overhead: {overhead:.1f}% (display only)")

    speedup = fresh.get("packed_speedup_batch32")
    if speedup is None:
        failures.append("fresh report lacks packed_speedup_batch32")
    elif speedup < args.min_speedup:
        failures.append(
            f"packed speedup {speedup:.2f}x < required {args.min_speedup:.1f}x"
        )
    else:
        print(f"packed/scalar speedup: {speedup:.1f}x (>= {args.min_speedup:.1f}x)")

    if base.get("provisional"):
        print(
            "baseline is provisional (recorded off-CI): "
            "skipping per-arm regression comparison"
        )
    else:
        fresh_arms = arm_map(fresh)
        for key, ref in sorted(arm_map(base).items()):
            got = fresh_arms.get(key)
            if got is None:
                continue  # arm removed or renamed: not a perf regression
            drop = (ref - got) / ref
            tag = f"{key[0]}(batch={key[1]})"
            if drop > args.max_regression:
                failures.append(
                    f"{tag}: {got:.3e} flips/s is {drop:.0%} below "
                    f"baseline {ref:.3e}"
                )
            else:
                print(f"{tag}: {got:.3e} vs baseline {ref:.3e} ({-drop:+.0%})")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
